"""ECM in-core model: hand-computed decompositions, stage wiring, and
the runtime-model property suite (monotonicity, core-count saturation,
crossover continuity)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.api.stages import (
    EqRuntimeModel,
    RooflineRuntimeModel,
    RUNTIME_MODELS,
    default_runtime_model,
    resolve_runtime_model,
    supported_runtime_models,
)
from repro.core.incore import (
    ClassTiming,
    ECMRuntimeModel,
    InCoreTimings,
    ecm_cycles,
    miss_fractions,
    shared_transfer_cy,
    t_comp_cy,
    t_lsu_cy,
    timings_of,
    transfer_cy,
)
from repro.core.runtime_model import OpCounts
from repro.hw.targets import (
    ALL_TARGETS,
    CPU_TARGETS,
    GPU_SM90_LIKE,
    HASWELL_I7_5960X,
    TPU_V5E,
)

HSW = HASWELL_I7_5960X
COUNTS = OpCounts(int_ops=4000.0, fp_ops=6000.0, div_ops=50.0,
                  loads=3000.0, stores=1000.0, total_bytes=32000.0)


def rates_for(target, value=0.9):
    return {lvl.name: value for lvl in target.levels}


# --- hand-computed pieces ----------------------------------------------------


def test_class_timing_effective_beta():
    t = ClassTiming(3.0, 1.0, 4)
    assert t.beta_effective == 0.25
    assert ClassTiming(3.0, 2.0).beta_effective == 2.0


def test_t_comp_throughput_is_busiest_port_group():
    tim = timings_of(HSW)
    # int: 4000*(1/4)=1000, fp: 6000*(1/2)=3000, div: 50*8=400
    assert t_comp_cy(tim, COUNTS, "throughput") == pytest.approx(3000.0)


def test_t_comp_latency_is_dependency_chain():
    tim = timings_of(HSW)
    # 4000*1 + 6000*3 + 50*20
    assert t_comp_cy(tim, COUNTS, "latency") == pytest.approx(23000.0)


def test_t_comp_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        t_comp_cy(timings_of(HSW), COUNTS, "warp-speed")


def test_t_lsu_counts_every_reference():
    tim = timings_of(HSW)
    # loads: 3000*(1/2)=1500, stores: 1000*1=1000
    assert t_lsu_cy(tim, COUNTS) == pytest.approx(2500.0)


def test_miss_fractions_from_cumulative_rates():
    assert miss_fractions([0.5, 0.75, 0.9]) == pytest.approx(
        [0.5, 0.25, 0.1])


def test_miss_fractions_clamped_monotone():
    # a non-monotone cumulative input cannot create traffic downstream
    out = miss_fractions([0.9, 0.5, 1.2])
    assert out == pytest.approx([0.1, 0.1, 0.0])


def test_transfer_cy_hand_computed():
    # Haswell betas beyond L1: L2=3, L3=8, RAM=14; 1000 references
    out = transfer_cy(HSW, [0.9, 0.95, 0.99], 1000.0)
    assert out == pytest.approx(
        [0.1 * 1000 * 3.0, 0.05 * 1000 * 8.0, 0.01 * 1000 * 14.0])


def test_transfer_cy_level_mismatch():
    with pytest.raises(ValueError, match="levels"):
        transfer_cy(HSW, [0.9, 0.95], 1000.0)


def test_shared_transfer_uses_undivided_counts():
    rates = [0.9, 0.95, 0.99]
    # Haswell shared_level=-1 -> L3 (index 2): the L2->L3 and L3->RAM
    # boundaries contend, L1->L2 stays private
    expected = (0.05 * COUNTS.mem_ops * 8.0
                + 0.01 * COUNTS.mem_ops * 14.0)
    assert shared_transfer_cy(HSW, rates, COUNTS) == pytest.approx(expected)


def test_ecm_cycles_throughput_decomposition():
    rates = [0.9, 0.95, 0.99]
    cyc = ecm_cycles(HSW, rates, COUNTS, mode="throughput")
    transfers = sum(transfer_cy(HSW, rates, COUNTS.mem_ops))
    assert cyc["t_comp_cy"] == pytest.approx(3000.0)
    assert cyc["t_data_cy"] == pytest.approx(2500.0 + transfers)
    assert cyc["t_core_cy"] == pytest.approx(
        max(cyc["t_comp_cy"], cyc["t_data_cy"]))


def test_ecm_cycles_latency_serializes():
    rates = [0.9, 0.95, 0.99]
    cyc = ecm_cycles(HSW, rates, COUNTS, mode="latency")
    assert cyc["t_core_cy"] == pytest.approx(
        cyc["t_comp_cy"] + cyc["t_data_cy"])
    assert cyc["t_data_cy"] > 0


def test_ecm_cycles_latency_level_mismatch():
    with pytest.raises(ValueError, match="levels"):
        ecm_cycles(HSW, [0.9], COUNTS, mode="latency")


def test_timings_of_prefers_percls_table():
    assert timings_of(HSW) is HSW.incore


def test_timings_of_derives_fallback_from_instr():
    import dataclasses

    bare = dataclasses.replace(HSW, incore=None)
    tim = timings_of(bare)
    assert tim.fp_ops.beta == HSW.instr.beta_fp
    assert tim.fp_ops.ports == 1
    assert tim.loads.delta == HSW.level_latency_cy[0]
    assert tim.loads.beta == HSW.level_beta_cy[0]


def test_timings_of_rejects_untimed_target():
    with pytest.raises(ValueError, match="neither"):
        timings_of(TPU_V5E)


def test_incore_tables_consistent_with_aggregate_betas():
    """The per-class port tables and the aggregate Eq. 4–7 timings
    describe the same silicon: beta_X == incore.X.beta / ports."""
    for t in CPU_TARGETS.values():
        assert t.incore.int_ops.beta_effective == t.instr.beta_int
        assert t.incore.fp_ops.beta_effective == t.instr.beta_fp
        assert t.incore.div_ops.beta_effective == t.instr.beta_div


# --- stage wiring ------------------------------------------------------------


def test_registry_names_match_model_attrs():
    for name, cls in RUNTIME_MODELS.items():
        assert cls.name == name


def test_supported_models_per_target():
    for t in CPU_TARGETS.values():
        assert supported_runtime_models(t) == ("eq", "ecm", "roofline")
    assert supported_runtime_models(GPU_SM90_LIKE) == (
        "eq", "ecm", "roofline")
    assert supported_runtime_models(TPU_V5E) == ("roofline",)


def test_resolve_runtime_model():
    assert isinstance(resolve_runtime_model("ecm", HSW), ECMRuntimeModel)
    assert isinstance(resolve_runtime_model(None, HSW), EqRuntimeModel)
    assert isinstance(resolve_runtime_model("auto", "tpu-v5e"),
                      RooflineRuntimeModel)
    with pytest.raises(ValueError, match="unknown runtime model"):
        resolve_runtime_model("nope", HSW)
    with pytest.raises(ValueError, match="does not support"):
        resolve_runtime_model("ecm", TPU_V5E)
    with pytest.raises(ValueError, match="needs a target"):
        resolve_runtime_model("auto")


def test_gpu_target_registered():
    assert ALL_TARGETS["gpu-sm"] is GPU_SM90_LIKE
    assert "gpu-sm" not in CPU_TARGETS  # paper matrix stays the 3 CPUs
    # GPU signature: much wider throughput than latency would suggest
    assert GPU_SM90_LIKE.incore.fp_ops.beta_effective < 0.1
    assert GPU_SM90_LIKE.incore.fp_ops.delta >= 4.0


def test_ecm_stage_interface_and_bound_labels():
    model = ECMRuntimeModel()
    out = model.runtime(HSW, rates_for(HSW), COUNTS, 2)
    for key in ("t_pred_s", "t_cpu_s", "t_mem_s", "t_shared_bw_s",
                "bound"):
        assert key in out
    assert out["t_pred_s"] > 0
    assert out["bound"] in ("bandwidth", "compute", "data")
    # compute-heavy mix on one core must be compute-bound
    heavy = OpCounts(fp_ops=1e9, loads=10.0, stores=0.0, total_bytes=80.0)
    assert model.runtime(
        HSW, rates_for(HSW, 1.0), heavy, 1)["bound"] == "compute"


def test_ecm_missing_level_key_raises():
    with pytest.raises(KeyError):
        ECMRuntimeModel().runtime(HSW, {"L1": 0.9}, COUNTS, 1)


def test_roofline_tpu_unchanged():
    """The generalized roofline must reproduce the original VMEM/HBM
    formula bit-for-bit on the TPU target."""
    model = RooflineRuntimeModel()
    for rate in (0.0, 0.37, 0.9, 1.0):
        for cores, mode in ((1, "throughput"), (4, "latency")):
            share = COUNTS.scaled(1.0 / cores)
            miss_bytes = (1.0 - rate) * share.total_bytes
            t_mem = miss_bytes / TPU_V5E.hbm_bandwidth
            if miss_bytes > 0.0:
                t_mem += TPU_V5E.vmem_latency_s
            t_cpu = share.fp_ops / TPU_V5E.peak_flops_bf16
            expected = (max(t_mem, t_cpu) if mode == "throughput"
                        else t_mem + t_cpu)
            got = model.runtime(TPU_V5E, {"VMEM": rate}, COUNTS, cores,
                                mode=mode)
            assert got["t_pred_s"] == expected
            assert got["t_mem_s"] == t_mem
            assert got["t_cpu_s"] == t_cpu


def test_default_model_unchanged():
    assert isinstance(default_runtime_model(HSW), EqRuntimeModel)
    assert isinstance(default_runtime_model(TPU_V5E), RooflineRuntimeModel)
    # the GPU target carries instr timings, so its default stays Eq
    assert isinstance(default_runtime_model(GPU_SM90_LIKE), EqRuntimeModel)


# --- property suite ----------------------------------------------------------

CPU_NAMES = sorted(CPU_TARGETS) + ["gpu-sm"]

rate_st = st.floats(min_value=0.0, max_value=1.0)
count_st = st.floats(min_value=0.0, max_value=1e7)
mode_st = st.sampled_from(["throughput", "latency"])


def _make_counts(ints, fps, divs, lds, sts_):
    return OpCounts(int_ops=ints, fp_ops=fps, div_ops=divs, loads=lds,
                    stores=sts_, total_bytes=(lds + sts_) * 8.0)


@settings(max_examples=60, deadline=None)
@given(
    target_name=st.sampled_from(CPU_NAMES),
    rates=st.lists(rate_st, min_size=3, max_size=3),
    bump_idx=st.integers(min_value=0, max_value=2),
    bump=st.floats(min_value=0.0, max_value=1.0),
    model_name=st.sampled_from(["ecm", "roofline", "eq"]),
    mode=mode_st,
)
def test_runtime_monotone_nonincreasing_in_hit_rates(
        target_name, rates, bump_idx, bump, model_name, mode):
    """Improving any level's hit rate never makes the prediction slower."""
    target = ALL_TARGETS[target_name]
    rates = rates[:len(target.levels)]
    bump_idx = bump_idx % len(target.levels)
    model = resolve_runtime_model(model_name, target)
    better = list(rates)
    better[bump_idx] = min(1.0, better[bump_idx] + bump)
    names = [lvl.name for lvl in target.levels]
    t_lo = model.runtime(target, dict(zip(names, rates)), COUNTS, 2,
                         mode=mode)["t_pred_s"]
    t_hi = model.runtime(target, dict(zip(names, better)), COUNTS, 2,
                         mode=mode)["t_pred_s"]
    assert t_hi <= t_lo + 1e-12 * max(t_lo, 1.0)


@settings(max_examples=60, deadline=None)
@given(
    target_name=st.sampled_from(CPU_NAMES),
    counts=st.tuples(count_st, count_st, count_st, count_st, count_st),
    field_idx=st.integers(min_value=0, max_value=4),
    extra=st.floats(min_value=0.0, max_value=1e7),
    model_name=st.sampled_from(["ecm", "roofline", "eq"]),
    mode=mode_st,
)
def test_runtime_monotone_nondecreasing_in_counts(
        target_name, counts, field_idx, extra, model_name, mode):
    """More work of any class never makes the prediction faster."""
    target = ALL_TARGETS[target_name]
    model = resolve_runtime_model(model_name, target)
    rates = rates_for(target, 0.9)
    more = list(counts)
    more[field_idx] += extra
    t_lo = model.runtime(target, rates, _make_counts(*counts), 2,
                         mode=mode)["t_pred_s"]
    t_hi = model.runtime(target, rates, _make_counts(*more), 2,
                         mode=mode)["t_pred_s"]
    assert t_hi >= t_lo - 1e-12 * max(t_hi, 1.0)


@settings(max_examples=40, deadline=None)
@given(
    target_name=st.sampled_from(CPU_NAMES),
    rates=st.lists(st.floats(min_value=0.1, max_value=0.99),
                   min_size=3, max_size=3),
)
def test_ecm_saturates_with_cores_once_bandwidth_bound(target_name, rates):
    """Per-core time scales 1/n, the chip-wide shared-transfer term
    does not — past the saturation point, doubling cores changes
    nothing and the prediction equals the shared-bandwidth term."""
    target = ALL_TARGETS[target_name]
    rates = rates[:len(target.levels)]
    names = [lvl.name for lvl in target.levels]
    rate_map = dict(zip(names, rates))
    model = ECMRuntimeModel()
    shared_cy = shared_transfer_cy(target, rates, COUNTS)
    assert shared_cy > 0  # rates < 1 guarantee shared-level traffic
    percore_cy = ecm_cycles(target, rates, COUNTS)["t_core_cy"]
    n_sat = max(1, math.ceil(percore_cy / shared_cy))
    t_sat = model.runtime(target, rate_map, COUNTS, n_sat)
    t_2x = model.runtime(target, rate_map, COUNTS, 2 * n_sat)
    sat_s = shared_cy * target.cycle_s
    assert t_sat["t_pred_s"] == pytest.approx(sat_s)
    assert t_2x["t_pred_s"] == pytest.approx(sat_s)
    assert t_2x["bound"] == "bandwidth"
    # and the curve is non-increasing on the way there
    prev = math.inf
    for n in (1, 2, n_sat, 2 * n_sat):
        cur = model.runtime(target, rate_map, COUNTS, n)["t_pred_s"]
        assert cur <= prev + 1e-15
        prev = cur


@settings(max_examples=40, deadline=None)
@given(
    target_name=st.sampled_from(CPU_NAMES),
    rates=st.lists(st.floats(min_value=0.1, max_value=0.99),
                   min_size=3, max_size=3),
    eps=st.floats(min_value=1e-6, max_value=1e-3),
)
def test_ecm_crossover_is_continuous(target_name, rates, eps):
    """Throughput mode is max(T_comp, T_data): scaling the fp work
    through the compute/data crossover moves the prediction by no more
    than the fp term's own slope — no jump at the switch."""
    target = ALL_TARGETS[target_name]
    rates = rates[:len(target.levels)]
    names = [lvl.name for lvl in target.levels]
    rate_map = dict(zip(names, rates))
    tim = timings_of(target)
    base = OpCounts(loads=3000.0, stores=1000.0, total_bytes=32000.0)
    data_cy = ecm_cycles(target, rates, base)["t_data_cy"]
    # fp count putting T_comp exactly at the crossover with T_data
    fp_star = data_cy / tim.fp_ops.beta_effective
    model = ECMRuntimeModel()

    def t(fp):
        c = OpCounts(fp_ops=fp, loads=base.loads, stores=base.stores,
                     total_bytes=base.total_bytes)
        return model.runtime(target, rate_map, c, 1)["t_pred_s"]

    delta_fp = eps * fp_star
    jump = abs(t(fp_star + delta_fp) - t(fp_star - delta_fp))
    slope_bound = 2 * delta_fp * tim.fp_ops.beta_effective * target.cycle_s
    assert jump <= slope_bound + 1e-18
