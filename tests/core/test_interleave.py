"""Algorithm 2 (interleaving) + CRD semantics (paper Table 3)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reuse.distance import reuse_distances
from repro.core.trace.interleave import interleave_traces
from repro.core.trace.types import LabeledTrace


def mk(addrs, shared=None):
    addrs = np.asarray(addrs, dtype=np.int64)
    shared = (
        np.zeros(len(addrs), dtype=bool)
        if shared is None
        else np.asarray(shared, dtype=bool)
    )
    return LabeledTrace(addrs, np.zeros(len(addrs), np.int32), shared)


def test_round_robin_pattern():
    t0, t1 = mk([1, 2, 3]), mk([10, 20, 30])
    il = interleave_traces([t0, t1], "round_robin")
    assert il.addresses.tolist() == [1, 10, 2, 20, 3, 30]


def test_round_robin_uneven_skips_exhausted():
    t0, t1 = mk([1, 2, 3, 4]), mk([10])
    il = interleave_traces([t0, t1], "round_robin")
    assert il.addresses.tolist() == [1, 10, 2, 3, 4]


def test_chunked():
    t0, t1 = mk([1, 2, 3, 4]), mk([10, 20, 30, 40])
    il = interleave_traces([t0, t1], "chunked", chunk_size=2)
    assert il.addresses.tolist() == [1, 2, 10, 20, 3, 4, 30, 40]


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.lists(st.integers(min_value=0, max_value=100), min_size=0, max_size=40),
        min_size=1,
        max_size=6,
    ),
    st.sampled_from(["round_robin", "uniform", "chunked"]),
)
def test_conservation_and_order(cores, strategy):
    traces = [mk(c) for c in cores]
    il = interleave_traces([t for t in traces], strategy, chunk_size=3, seed=7)
    # conservation: multiset of addresses preserved
    allconc = np.concatenate([t.addresses for t in traces])
    assert sorted(il.addresses.tolist()) == sorted(allconc.tolist())
    assert len(il) == len(allconc)


def test_uniform_preserves_per_core_order():
    t0 = mk(list(range(100)))
    t1 = mk(list(range(1000, 1100)))
    il = interleave_traces([t0, t1], "uniform", seed=3)
    a = il.addresses
    sub0 = a[a < 1000]
    sub1 = a[a >= 1000]
    assert (np.diff(sub0) > 0).all() and (np.diff(sub1) > 0).all()


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.integers(min_value=0, max_value=50), min_size=2, max_size=5
    ),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_uniform_unequal_lengths_fifo_and_permutation(lengths, seed):
    """ISSUE-2 satellite: with UNEQUAL-length traces the bulk sampler's
    exhaustion-cut path (`_uniform_choice_sequence`) must still emit an
    exact permutation that preserves per-core FIFO order."""
    traces = [
        mk(np.arange(n, dtype=np.int64) + 1000 * c)
        for c, n in enumerate(lengths)
    ]
    il = interleave_traces(traces, "uniform", seed=seed)
    allconc = np.concatenate([t.addresses for t in traces])
    # exact permutation: same multiset, same total length
    assert len(il) == len(allconc)
    assert sorted(il.addresses.tolist()) == sorted(allconc.tolist())
    # per-core FIFO: the subsequence of each core's (disjoint) address
    # range equals that core's trace, in order
    for c, t in enumerate(traces):
        lo, hi = 1000 * c, 1000 * c + 1000
        sub = il.addresses[(il.addresses >= lo) & (il.addresses < hi)]
        assert np.array_equal(sub, t.addresses)


def test_uniform_seeds_differ():
    t0 = mk(list(range(50)))
    t1 = mk(list(range(1000, 1050)))
    a = interleave_traces([t0, t1], "uniform", seed=0).addresses
    b = interleave_traces([t0, t1], "uniform", seed=1).addresses
    assert not np.array_equal(a, b)


def test_paper_table3_crd_effects():
    """Table 3: dilation, overlap, interception on the shared trace."""
    # shared trace from Table 3: u w v u y x v x u v
    shared = [ord(c) for c in "uwvuyxvxuv"]
    crd = reuse_distances(shared)
    assert crd[3] == 2  # u at time 4: CRD 2 (dilation: PRD was 1)
    assert crd[8] == 3  # u at time 9: CRD 3 not 4 (overlap: x shared)
    assert crd[9] == 2  # v at time 10: CRD 2 < PRD (interception)
    # core C1's private trace: u v u y x u v
    prd = reuse_distances([ord(c) for c in "uvuyxuv"])
    assert prd[2] == 1  # u's PRD at time 4 == 1 (dilation reference)
