"""Fused device-binned profiles (ISSUE-5 tentpole): the accumulated
kernels/reuse_hist histogram must equal the reference binning of the
exact host distances — weighted and all-first-touch cases included —
and the streaming fused build must match the one-shot build."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import sdcm
from repro.core.reuse.distance import INF_RD, reuse_distances
from repro.core.reuse.fused import (
    FusedReuseHistogram,
    binned_profile_from_distances,
    binned_profile_windows,
    profile_from_binned_hist,
)
from repro.core.reuse.profile import profile_from_distances
from repro.kernels.reuse_hist import reuse_hist_ref
from repro.kernels.reuse_hist.reuse_hist import NUM_BINS, _bin_ids


def _ref_counts(rds, weights=None):
    w = (np.ones(len(rds), np.float32) if weights is None
         else np.asarray(weights, np.float32))
    return np.asarray(
        reuse_hist_ref(jnp.asarray(np.asarray(rds, np.float32)),
                       jnp.asarray(w))
    )


def _bin_of(d: int) -> int:
    if d < 0:
        return 0
    return int(np.asarray(_bin_ids(jnp.asarray([float(d)])))[0])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=-1, max_value=1 << 20), min_size=1,
                max_size=600))
def test_fused_counts_equal_ref_binning(distances):
    rds = np.asarray(distances, dtype=np.int64)
    hist = FusedReuseHistogram().update(jnp.asarray(rds)).histogram()
    assert np.array_equal(hist[0], _ref_counts(rds))


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(min_value=-1, max_value=1 << 16), min_size=1,
             max_size=200),
    st.lists(st.floats(min_value=0.0, max_value=8.0, allow_nan=False),
             min_size=1, max_size=200),
)
def test_fused_weighted_counts_equal_ref_binning(distances, weights):
    n = min(len(distances), len(weights))
    rds = np.asarray(distances[:n], dtype=np.int64)
    w = np.asarray(weights[:n], dtype=np.float32)
    hist = FusedReuseHistogram().update(jnp.asarray(rds),
                                        jnp.asarray(w)).histogram()
    np.testing.assert_allclose(hist[0], _ref_counts(rds, w), rtol=1e-6,
                               atol=1e-5)


def test_all_first_touch_edge_case():
    rds = np.full(257, INF_RD, dtype=np.int64)
    prof = binned_profile_from_distances(rds)
    assert prof.distances.tolist() == [INF_RD]
    assert prof.counts.tolist() == [257]
    assert prof.inf_fraction == 1.0
    # and through the histogram: all mass in bin 0, zero distance mass
    hist = FusedReuseHistogram().update(jnp.asarray(rds)).histogram()
    assert hist[0][0] == 257 and hist[0][1:].sum() == 0
    assert hist[1].sum() == 0


def test_empty_profile():
    prof = binned_profile_from_distances(np.empty(0, dtype=np.int64))
    assert prof.total == 0 and len(prof.distances) == 0


def test_binned_profile_structure():
    """Each profile entry sits inside its bin with the bin's count."""
    rng = np.random.default_rng(0)
    rds = rng.integers(-1, 1 << 14, size=3000)
    prof = binned_profile_from_distances(rds)
    ref = _ref_counts(rds)
    assert prof.total == len(rds)
    got = np.zeros(NUM_BINS)
    for d, c in zip(prof.distances, prof.counts):
        got[_bin_of(int(d))] += c
    assert np.array_equal(got, ref)
    # representatives are weighted means, so each stays inside its bin
    for d in prof.distances:
        if d < 0:
            continue
        b = _bin_of(int(d))
        lo = 0 if b == 1 else 1 << (b - 1)
        hi = (1 << b) - 1 if b < NUM_BINS - 1 else np.iinfo(np.int64).max
        assert lo <= d <= hi


def test_streaming_fused_matches_one_shot():
    """Windowed accumulation == one-shot histogram of the full trace.

    Distances are small enough that the f32 mass sums are exact in any
    summation order, so the comparison is bit-level."""
    rng = np.random.default_rng(1)
    trace = rng.integers(0, 700, size=5000) * 64
    one_shot = binned_profile_from_distances(reuse_distances(trace, 64))
    for ws in (256, 1000, 4096):
        streamed = binned_profile_windows(trace, 64, window_size=ws)
        assert np.array_equal(streamed.distances, one_shot.distances)
        assert np.array_equal(streamed.counts, one_shot.counts)


def test_binned_sdcm_tracks_exact_and_host_binning():
    """SDCM hit rates from the fused binned profile track the exact
    profile — and never degrade on the host log2_binned coarsening.

    A uniform-random trace is adversarial for log2 binning (all its
    mass sits in the P(h|D) transition bins), so the bound here is the
    binning's intrinsic ~5e-3; on the paper's structured workloads the
    deviation is ~3e-5 and the validation runner gates it at 1e-3
    (tests/validate/test_runner.py)."""
    from repro.core.reuse.profile import log2_binned

    rng = np.random.default_rng(2)
    trace = rng.integers(0, 1 << 12, size=20000) * 64
    rds = reuse_distances(trace, 64)
    exact = profile_from_distances(rds)
    binned = binned_profile_from_distances(rds)
    host = log2_binned(exact)
    for assoc, blocks in ((8, 512), (16, 8192), (20, 65536)):
        a = sdcm.hit_rate(exact, assoc, blocks)
        b = sdcm.hit_rate(binned, assoc, blocks)
        c = sdcm.hit_rate(host, assoc, blocks)
        assert abs(a - b) < 5e-3, (assoc, blocks, a, b)
        # the device binning is no coarser than the host binning
        assert abs(a - b) <= abs(a - c) + 1e-6


def test_profile_from_binned_hist_rounding():
    hist = np.zeros((2, NUM_BINS))
    hist[0, 0] = 3        # three first touches
    hist[0, 5] = 4        # four distances in [16, 32)
    hist[1, 5] = 4 * 21.0
    prof = profile_from_binned_hist(hist)
    assert prof.distances.tolist() == [INF_RD, 21]
    assert prof.counts.tolist() == [3, 4]
    # a mass that rounds outside the bin is clamped back in
    hist[1, 5] = 4 * 1000.0
    prof = profile_from_binned_hist(hist)
    assert prof.distances.tolist() == [INF_RD, 31]
