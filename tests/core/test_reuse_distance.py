"""Reuse distance: paper Table 1 golden values + oracle equivalence."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reuse.distance import (
    INF_RD,
    _IdMap,
    compact_ids,
    per_set_reuse_distances,
    reuse_distances,
    reuse_distances_ref,
)


def test_paper_table1_golden():
    # w x w y x z z w  ->  inf inf 1 inf 2 inf 0 3
    trace = [ord(c) for c in "wxwyxzzw"]
    expected = [INF_RD, INF_RD, 1, INF_RD, 2, INF_RD, 0, 3]
    assert reuse_distances_ref(trace).tolist() == expected
    assert reuse_distances(trace).tolist() == expected


def test_first_touch_is_inf():
    rds = reuse_distances(np.arange(100))
    assert (rds == INF_RD).all()


def test_repeated_single_address():
    rds = reuse_distances(np.zeros(50, dtype=np.int64))
    assert rds[0] == INF_RD
    assert (rds[1:] == 0).all()


def test_line_granularity():
    # addresses within the same 64B line are one element
    addrs = np.array([0, 8, 16, 64, 0])
    rds = reuse_distances(addrs, line_size=64)
    assert rds.tolist() == [INF_RD, 0, 0, INF_RD, 1]


@settings(max_examples=80, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=40), min_size=1, max_size=400)
)
def test_fenwick_matches_stack_oracle(trace):
    t = np.asarray(trace, dtype=np.int64)
    assert np.array_equal(reuse_distances(t), reuse_distances_ref(t))


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=10_000), min_size=1, max_size=300)
)
def test_rd_bounded_by_distinct_count(trace):
    t = np.asarray(trace, dtype=np.int64)
    rds = reuse_distances(t)
    m = len(np.unique(t))
    assert rds.max(initial=INF_RD) < m
    # every address's first touch is INF, exactly m INF entries
    assert int((rds == INF_RD).sum()) == m


def test_per_set_equals_global_with_one_set():
    rng = np.random.default_rng(0)
    t = rng.integers(0, 1 << 16, size=2000)
    a = per_set_reuse_distances(t, line_size=64, num_sets=1)
    b = reuse_distances(t, line_size=64)
    assert np.array_equal(a, b)


def test_per_set_partitions_correctly():
    # two sets; same-set accesses interleaved with other-set noise must
    # not inflate the distance
    line = 64
    # lines 0,2,4 -> set 0 ; lines 1,3 -> set 1 (2 sets)
    addrs = np.array([0, 64, 128, 64 * 3, 0]) * 1
    rds = per_set_reuse_distances(addrs, line_size=line, num_sets=2)
    # final access to line 0: only line 2 (set 0) intervenes -> distance 1
    assert rds[-1] == 1


def test_compact_ids_dense():
    ids = compact_ids(np.array([10**12, 5, 10**12, 7]))
    assert ids.max() == 2 and ids.min() == 0
    assert ids[0] == ids[2]


def test_empty_trace():
    assert reuse_distances(np.empty(0, dtype=np.int64)).size == 0


# --- _IdMap: incremental position fix-up (ISSUE-5 satellite) --------------


def test_idmap_stable_across_calls():
    """The same key must map to the same id on every call, including
    calls that insert new keys before it in sort order."""
    m = _IdMap()
    first = m.map(np.array([50, 10, 50, 99], dtype=np.int64))
    assert first.tolist() == [1, 0, 1, 2]  # ids in sorted-unique order
    # new keys straddling the known ones force index fix-ups
    second = m.map(np.array([5, 10, 75, 50, 99, 5], dtype=np.int64))
    assert second[1] == first[1] and second[3] == first[0]
    assert second[4] == first[3]
    assert second[0] == second[5]  # new key, consistent within the call
    third = m.map(np.array([50, 10, 99, 5, 75], dtype=np.int64))
    assert third.tolist() == [
        second[3], second[1], second[4], second[0], second[2],
    ]


@settings(max_examples=40, deadline=None)
@given(st.lists(
    st.lists(st.integers(min_value=-(1 << 40), max_value=1 << 40),
             min_size=1, max_size=30),
    min_size=1, max_size=6,
))
def test_idmap_incremental_matches_fresh_map(batches):
    """Mapping batch-by-batch must agree with one shot over the concat:
    ids are assigned in first-appearance order of np.unique batches, so
    re-mapping the full history in a fresh _IdMap reproduces them."""
    inc = _IdMap()
    seen: list[np.ndarray] = []
    for batch in batches:
        arr = np.asarray(batch, dtype=np.int64)
        got = inc.map(arr)
        seen.append(arr)
        # every id below the running count, dense, and self-consistent
        assert got.max(initial=0) < inc.n
        again = inc.map(arr)
        assert np.array_equal(got, again)
    history = np.concatenate(seen)
    fresh = _IdMap()
    for arr in seen:
        fresh.map(arr)
    assert np.array_equal(inc.map(history), fresh.map(history))
