"""ISSUE-2 tentpole: streaming pipeline vs in-memory oracle.

The acceptance invariant — ``reuse_distances_streaming`` is
bit-identical to the monolithic Fenwick pass for every window size,
including windows that don't divide N — plus the streaming interleaver
and incremental profile accumulation.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reuse.distance import (
    INF_RD,
    reuse_distance_windows,
    reuse_distances,
    reuse_distances_ref,
    reuse_distances_streaming,
)
from repro.core.reuse.profile import (
    ReuseProfile,
    profile_from_distances,
    profile_from_distances_incremental,
    profile_from_pairs,
)
from repro.core.trace.interleave import interleave_traces, interleave_windows
from repro.core.trace.types import ChunkedTraceSource, LabeledTrace


def mk(addrs):
    addrs = np.asarray(addrs, dtype=np.int64)
    return LabeledTrace(
        addrs,
        (np.arange(len(addrs)) % 3).astype(np.int32),
        np.zeros(len(addrs), dtype=bool),
    )


def assert_profiles_equal(a: ReuseProfile, b: ReuseProfile):
    assert np.array_equal(a.distances, b.distances)
    assert np.array_equal(a.counts, b.counts)
    assert a.total == b.total


# --- reuse_distances_streaming ---------------------------------------------


def test_table1_golden_streamed():
    trace = [ord(c) for c in "wxwyxzzw"]
    expected = [INF_RD, INF_RD, 1, INF_RD, 2, INF_RD, 0, 3]
    for ws in (1, 2, 3, 8, 100):
        assert reuse_distances_streaming(
            trace, window_size=ws
        ).tolist() == expected


def test_streaming_bit_identical_across_window_sizes():
    """The acceptance criterion: >= 3 window sizes, including ones that
    do not divide N."""
    rng = np.random.default_rng(7)
    n = 5000
    trace = rng.integers(0, 400 * 64, size=n)
    ref = reuse_distances(trace, 64)
    for ws in (64, 333, 1024, 4096, 8192):  # 333/4096 don't divide 5000
        got = reuse_distances_streaming(trace, 64, window_size=ws)
        assert np.array_equal(ref, got), ws


def test_streaming_bit_identical_on_seed_workload_trace():
    """Same acceptance check on a real traced workload (ATAX)."""
    from repro.workloads.polybench import make_atax

    addrs = make_atax(n=32).trace().addresses
    ref = reuse_distances(addrs, 64)
    for ws in (256, 1000, 4096):
        assert np.array_equal(
            ref, reuse_distances_streaming(addrs, 64, window_size=ws)
        )


def test_streaming_line_granularity_and_empty():
    addrs = np.array([0, 8, 16, 64, 0])
    assert reuse_distances_streaming(
        addrs, 64, window_size=2
    ).tolist() == [INF_RD, 0, 0, INF_RD, 1]
    assert reuse_distances_streaming(np.empty(0, np.int64)).size == 0


def test_streaming_accepts_labeled_trace_and_window_iterators():
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 2000, size=1200)
    trace = mk(addrs)
    assert isinstance(trace, ChunkedTraceSource)
    ref = reuse_distances(addrs, 64)
    got = reuse_distances_streaming(trace, 64, window_size=100)
    assert np.array_equal(ref, got)
    # an explicit iterator of LabeledTrace windows streams identically
    got2 = np.concatenate(
        list(reuse_distance_windows(trace.windows(100), 64, window_size=100))
    )
    assert np.array_equal(ref, got2)


def test_streaming_window_shapes():
    rng = np.random.default_rng(5)
    addrs = rng.integers(0, 500, size=1000)
    wins = list(reuse_distance_windows(addrs, window_size=300))
    assert [len(w) for w in wins] == [300, 300, 300, 100]
    assert np.array_equal(np.concatenate(wins), reuse_distances(addrs))


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=50), min_size=1, max_size=150),
    st.integers(min_value=1, max_value=60),
)
def test_streaming_matches_oracle_property(trace, window_size):
    t = np.asarray(trace, dtype=np.int64)
    assert np.array_equal(
        reuse_distances_streaming(t, window_size=window_size),
        reuse_distances_ref(t),
    )


@pytest.mark.slow
def test_streaming_large_trace_bit_identical():
    """Large-trace regression (marked slow): many compaction cycles."""
    rng = np.random.default_rng(11)
    n = 120_000
    # hot/cold mix -> realistic working set churn
    hot = rng.integers(0, 2_000, size=n // 2)
    cold = rng.integers(0, 200_000, size=n - n // 2)
    trace = np.concatenate([hot, cold]) * 64
    rng.shuffle(trace)
    ref = reuse_distances(trace, 64)
    for ws in (4096, 30_000):
        assert np.array_equal(
            ref, reuse_distances_streaming(trace, 64, window_size=ws)
        )


# --- incremental profiles ---------------------------------------------------


def test_profile_incremental_equals_monolithic():
    rng = np.random.default_rng(9)
    addrs = rng.integers(0, 300 * 64, size=4000)
    ref = profile_from_distances(reuse_distances(addrs, 64))
    for ws in (128, 1000, 4096):
        inc = profile_from_distances_incremental(
            reuse_distance_windows(addrs, 64, window_size=ws)
        )
        assert_profiles_equal(ref, inc)
    assert profile_from_distances_incremental(iter([])).total == 0


def test_profile_merge():
    a = profile_from_pairs([INF_RD, 1, 5], [2, 3, 1])
    b = profile_from_pairs([1, 7], [4, 2])
    merged = ReuseProfile.merge([a, b])
    assert merged.distances.tolist() == [INF_RD, 1, 5, 7]
    assert merged.counts.tolist() == [2, 7, 1, 2]
    assert merged.total == 12
    assert_profiles_equal(merged, a.merged_with(b))
    assert ReuseProfile.merge([]).total == 0


# --- streaming interleaver --------------------------------------------------


@pytest.mark.parametrize("strategy,chunk", [
    ("round_robin", 1), ("chunked", 3), ("chunked", 7),
])
def test_interleave_windows_matches_in_memory(strategy, chunk):
    rng = np.random.default_rng(21)
    traces = [
        mk(rng.integers(0, 100, size=L)) for L in (83, 0, 40, 17)
    ]
    ref = interleave_traces(traces, strategy, chunk_size=chunk)
    for ws in (1, 16, 37, 1000):
        wins = list(
            interleave_windows(
                traces, strategy, window_size=ws, chunk_size=chunk
            )
        )
        assert all(len(w) == ws for w in wins[:-1])
        got = np.concatenate([w.addresses for w in wins])
        assert np.array_equal(got, ref.addresses)
        assert np.array_equal(
            np.concatenate([w.bb_ids for w in wins]), ref.bb_ids
        )


def test_interleave_windows_streamed_crd_equals_in_memory_crd():
    """End-to-end: streamed shared-trace windows -> streamed RD ->
    incremental profile == materialize-everything profile."""
    rng = np.random.default_rng(33)
    traces = [mk(rng.integers(0, 5000, size=L) * 8) for L in (900, 450)]
    shared = interleave_traces(traces, "round_robin")
    ref = profile_from_distances(reuse_distances(shared.addresses, 64))
    wins = interleave_windows(traces, "round_robin", window_size=256)
    inc = profile_from_distances_incremental(
        reuse_distance_windows(wins, 64, window_size=256)
    )
    assert_profiles_equal(ref, inc)


def test_interleave_windows_rejects_uniform():
    with pytest.raises(ValueError, match="uniform"):
        next(interleave_windows([mk([1, 2])], "uniform"))
