"""Exact LRU simulator vs a brute-force reference implementation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cachesim import (
    CacheLevelConfig,
    simulate_hierarchy,
    simulate_level,
)


def brute_force_lru(addresses, cfg: CacheLevelConfig) -> np.ndarray:
    """Straightforward set-associative LRU — the slow reference."""
    sets: list[list[int]] = [[] for _ in range(cfg.num_sets)]
    hits = np.zeros(len(addresses), dtype=bool)
    for i, a in enumerate(addresses):
        line = a // cfg.line_size
        s = line % cfg.num_sets
        ways = sets[s]
        if line in ways:
            hits[i] = True
            ways.remove(line)
        elif len(ways) >= cfg.effective_assoc:
            ways.pop()  # evict LRU (tail)
        ways.insert(0, line)
    return hits


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=4095), min_size=1, max_size=500),
    st.sampled_from([(256, 16, 1), (256, 16, 4), (512, 32, 2), (1024, 64, 16)]),
)
def test_matches_brute_force(addresses, geometry):
    size, line, assoc = geometry
    cfg = CacheLevelConfig("T", size, line, assoc)
    addrs = np.asarray(addresses, dtype=np.int64)
    got = simulate_level(addrs, cfg)
    want = brute_force_lru(addrs, cfg)
    assert np.array_equal(got, want)


def test_fully_associative():
    cfg = CacheLevelConfig("FA", 4 * 64, 64, 1000)  # 4 lines, fully assoc
    # touch 4 lines then the first again -> still resident
    addrs = np.array([0, 64, 128, 192, 0])
    assert simulate_level(addrs, cfg).tolist() == [False] * 4 + [True]
    # 5 distinct lines evicts the first
    addrs = np.array([0, 64, 128, 192, 256, 0])
    assert simulate_level(addrs, cfg).tolist() == [False] * 5 + [False]


def test_hierarchy_cumulative_metric():
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 1 << 16, size=5000)
    levels = [
        CacheLevelConfig("L1", 1024, 64, 4),
        CacheLevelConfig("L2", 16 * 1024, 64, 8),
    ]
    res = simulate_hierarchy(addrs, levels)
    # cumulative: level hit rates are non-decreasing down the hierarchy
    assert res[1].cumulative_hit_rate >= res[0].cumulative_hit_rate
    # L2 sees exactly the L1 misses
    assert res[1].accesses == res[0].accesses - res[0].hits
    # identity: 1 - cum_rate_L2 == L2 misses / total
    miss2 = res[1].accesses - res[1].hits
    assert abs((1 - res[1].cumulative_hit_rate) - miss2 / 5000) < 1e-12


def test_empty():
    assert simulate_hierarchy([], [CacheLevelConfig("L1", 1024, 64, 4)])[0].hits == 0
