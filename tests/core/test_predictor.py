"""End-to-end PPT-Multicore predictor vs exact-LRU ground truth."""
import numpy as np
import pytest

from repro.core.predictor import PPTMulticorePredictor
from repro.core.runtime_model import OpCounts
from repro.core.tasklist import Task, load_tasklist, save_tasklist
from repro.core.trace.types import trace_from_blocks
from repro.hw.targets import BROADWELL_E5_2699V4, HASWELL_I7_5960X


def strided_workload(iters=1500, stride=8, shared_period=1):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append(
            (
                "OUT__1__.for.body",
                np.array([A0 + stride * i, B0 + stride * (i % 128), 0]),
                np.array([False, False, True]),
            )
        )
    return trace_from_blocks(blocks)


COUNTS = OpCounts(
    int_ops=3000, fp_ops=1500, div_ops=10, loads=3000, stores=1500,
    total_bytes=4500 * 8,
)


@pytest.mark.parametrize("cores", [1, 2, 4, 8])
def test_hit_rates_close_to_exact_lru(cores):
    """The paper reports 1.23% average hit-rate error; on mimicked
    traces vs exact LRU, SDCM should stay within a few percent."""
    tr = strided_workload()
    pred = PPTMulticorePredictor(HASWELL_I7_5960X)
    p = pred.predict(tr, cores, COUNTS)
    gt = pred.ground_truth_hit_rates(tr, cores)
    for name, rate in p.hit_rates.items():
        assert 0.0 <= rate <= 1.0
        assert abs(rate - gt[name]) < 0.05, (name, rate, gt[name])


def test_sweep_cores_single_trace():
    tr = strided_workload()
    pred = PPTMulticorePredictor(HASWELL_I7_5960X)
    preds = pred.sweep_cores(tr, [1, 2, 4, 8], COUNTS)
    times = [p.t_pred_s for p in preds]
    # workload divides evenly -> predicted runtime decreases with cores
    assert all(t2 < t1 for t1, t2 in zip(times, times[1:]))


def test_runtime_positive_and_decomposes():
    tr = strided_workload()
    pred = PPTMulticorePredictor(BROADWELL_E5_2699V4)
    p = pred.predict(tr, 4, COUNTS)
    assert p.t_pred_s == pytest.approx(p.t_mem_s + p.t_cpu_s)
    assert p.t_mem_s > 0 and p.t_cpu_s > 0


def test_interleave_strategy_changes_shared_level_only_slightly():
    tr = strided_workload()
    pred = PPTMulticorePredictor(HASWELL_I7_5960X)
    a = pred.predict(tr, 4, COUNTS, strategy="round_robin")
    b = pred.predict(tr, 4, COUNTS, strategy="uniform", seed=11)
    # private levels identical (same private traces)
    assert a.hit_rates["L1"] == pytest.approx(b.hit_rates["L1"], abs=1e-9)
    # shared level may differ, but within a sane band
    assert abs(a.hit_rates["L3"] - b.hit_rates["L3"]) < 0.1


def test_tasklist_roundtrip(tmp_path):
    tr = strided_workload(iters=200)
    pred = PPTMulticorePredictor(HASWELL_I7_5960X)
    p = pred.predict(tr, 4, COUNTS, keep_profiles=True)
    task = Task(
        name="strided",
        num_cores=4,
        counts=COUNTS,
        block_bytes=8,
        private_profile=p.private_profile,
        shared_profile=p.shared_profile,
    )
    path = str(tmp_path / "tasklist.json")
    save_tasklist([task], path)
    (loaded,) = load_tasklist(path)
    assert loaded.name == "strided"
    np.testing.assert_array_equal(
        loaded.private_profile.distances, p.private_profile.distances
    )
    assert loaded.counts.total_bytes == COUNTS.total_bytes
