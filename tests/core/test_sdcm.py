"""SDCM (Eq. 1-3): oracle agreement, bounds, monotonicity."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reuse.profile import profile_from_trace
from repro.core.sdcm import hit_rate, phit_given_d, phit_given_d_np


def test_direct_mapped_formula():
    # Eq. 2: ((B-1)/B)^D
    d = np.array([0, 1, 10, 100])
    b = 64
    expected = ((b - 1) / b) ** d.astype(float)
    got = np.asarray(phit_given_d(d, 1, b))
    np.testing.assert_allclose(got, expected, rtol=1e-5)


def test_inf_distance_never_hits():
    assert float(phit_given_d(np.array([-1]), 8, 512)[0]) == 0.0
    assert phit_given_d_np(np.array([-1]), 8, 512)[0] == 0.0


def test_small_distance_always_hits():
    # D <= A-1 can't overflow the set
    for A, B in [(4, 64), (8, 512), (20, 4096)]:
        d = np.arange(A)
        assert np.allclose(np.asarray(phit_given_d(d, A, B)), 1.0)
        assert np.allclose(phit_given_d_np(d, A, B), 1.0)


def test_fully_associative_is_exact_lru():
    # A == B: hit iff D < B
    d = np.array([0, 63, 64, 100, -1])
    got = np.asarray(phit_given_d(d, 64, 64))
    np.testing.assert_allclose(got, [1, 1, 0, 0, 0])


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=2, max_value=24),
    st.integers(min_value=2, max_value=14),
    st.lists(st.integers(min_value=-1, max_value=100_000), min_size=1, max_size=32),
)
def test_jax_matches_float64_oracle(assoc, log_blocks, distances):
    blocks = 2 ** log_blocks
    if assoc > blocks:
        assoc = blocks
    d = np.asarray(distances, dtype=np.int64)
    a = np.asarray(phit_given_d(d, assoc, blocks), dtype=np.float64)
    b = phit_given_d_np(d, assoc, blocks)
    np.testing.assert_allclose(a, b, atol=3e-4)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=16),
    st.integers(min_value=4, max_value=12),
    st.integers(min_value=0, max_value=50_000),
)
def test_bounds_and_monotonicity_in_capacity(assoc, log_blocks, d):
    """P(h|D) in [0,1] and grows with cache size at fixed associativity."""
    b1, b2 = 2 ** log_blocks, 2 ** (log_blocks + 1)
    d_arr = np.array([d])
    p1 = phit_given_d_np(d_arr, assoc, b1)[0]
    p2 = phit_given_d_np(d_arr, assoc, b2)[0]
    assert 0.0 <= p1 <= 1.0 and 0.0 <= p2 <= 1.0
    assert p2 >= p1 - 1e-12


def test_monotonically_decreasing_in_distance():
    d = np.arange(0, 2000, 7)
    p = phit_given_d_np(d, 8, 512)
    assert (np.diff(p) <= 1e-12).all()


def test_hit_rate_from_profile_table2():
    # Table 1/2 trace with a fully-assoc cache of 4 blocks: the paper
    # notes "none of the memory references will cause a capacity miss"
    # -> all finite-D references hit; only the 4 compulsory misses miss.
    trace = [ord(c) for c in "wxwyxzzw"]
    prof = profile_from_trace(trace)
    p = hit_rate(prof, 4, 4)
    assert abs(p - 0.5) < 1e-12  # 4 hits / 8 accesses
