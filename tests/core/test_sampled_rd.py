"""Sampled reuse-distance accelerator (beyond-paper, Schuff-style)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import sdcm
from repro.core.reuse.distance import (
    INF_RD, reuse_distances, reuse_distances_sampled,
)
from repro.core.reuse.profile import profile_from_distances, profile_from_pairs


def _profile_from_sampled(d, w):
    finite = d >= 0
    vals, inv = np.unique(d[finite], return_inverse=True)
    counts = np.zeros(len(vals))
    np.add.at(counts, inv, w[finite])
    dists = np.concatenate([[INF_RD], vals.astype(np.int64)])
    cnts = np.concatenate([[w[~finite].sum()], counts])
    return profile_from_pairs(dists, np.round(cnts).astype(np.int64))


def _mix_trace(n=30_000, seed=1):
    rng = np.random.default_rng(seed)
    tr = np.concatenate([
        rng.integers(0, 128, n // 2),       # hot
        rng.integers(0, n // 4, n - n // 2) # cold-ish
    ]) * 64
    rng.shuffle(tr)
    return tr


def test_sampled_hit_rate_close_to_exact():
    tr = _mix_trace()
    exact_prof = profile_from_distances(reuse_distances(tr, 64))
    d, w = reuse_distances_sampled(tr, 64, rate=0.08, seed=3)
    samp_prof = _profile_from_sampled(d, w)
    for blocks, assoc in ((512, 8), (4096, 8)):
        e = sdcm.hit_rate(exact_prof, assoc, blocks)
        s = sdcm.hit_rate(samp_prof, assoc, blocks)
        assert abs(e - s) < 0.02, (blocks, e, s)


def test_sampled_weights_conserve_mass():
    tr = _mix_trace(8_000)
    d, w = reuse_distances_sampled(tr, 64, rate=0.1)
    assert w.sum() == pytest.approx(len(tr), rel=1e-9)


def test_sampled_cold_misses_marked():
    tr = (np.arange(500) * 64)  # every access cold
    d, w = reuse_distances_sampled(tr, 64, rate=0.5)
    assert (d == -1).all()
