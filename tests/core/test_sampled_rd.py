"""SHARDS-sampled reuse profiles (core/reuse/sampled.py): estimator
properties — unbiasedness within the declared bound, rate-1.0
bit-identity, per-(seed, rate) determinism, and bound monotonicity."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sdcm
from repro.core.reuse import (
    SAMPLE_BOUND_DELTA,
    reuse_distances,
    sample_lines_mask,
    sampled_profile_windows,
    sampled_reuse_profile,
    sampling_error_bound,
)
from repro.core.reuse.profile import profile_from_distances


def _mix_trace(n=30_000, seed=1):
    rng = np.random.default_rng(seed)
    tr = np.concatenate([
        rng.integers(0, 128, n // 2),        # hot
        rng.integers(0, n // 4, n - n // 2)  # cold-ish
    ]) * 64
    rng.shuffle(tr)
    return tr


@pytest.fixture(scope="module")
def trace():
    return _mix_trace()


@pytest.fixture(scope="module")
def exact_profile(trace):
    return profile_from_distances(reuse_distances(trace, 64))


# --- unbiasedness within the declared bound --------------------------------


def test_sampled_hit_rate_within_declared_bound(trace, exact_profile):
    """Every seeded trial's SDCM hit rate deviates from the exact
    profile's by less than the bound the sampled profile declares."""
    for blocks, assoc in ((512, 8), (4096, 8)):
        e = sdcm.hit_rate(exact_profile, assoc, blocks)
        for seed in range(5):
            prof = sampled_reuse_profile(trace, 64, rate=0.25, seed=seed)
            s = sdcm.hit_rate(prof, assoc, blocks)
            assert prof.error_bound is not None and prof.error_bound > 0
            assert abs(e - s) < prof.error_bound, (blocks, seed, e, s)


def test_sampled_estimator_unbiased_over_seeds(trace, exact_profile):
    """The MEAN hit rate over independent seeds lands much closer to
    the exact value than any single trial's bound — the rescaled
    histogram is an unbiased estimator, not just a bounded one."""
    blocks, assoc = 1024, 8
    e = sdcm.hit_rate(exact_profile, assoc, blocks)
    trials = [
        sdcm.hit_rate(
            sampled_reuse_profile(trace, 64, rate=0.25, seed=seed),
            assoc, blocks,
        )
        for seed in range(10)
    ]
    bound = sampling_error_bound(0.25, len(trace))
    assert abs(np.mean(trials) - e) < bound / 2


def test_sampled_counts_conserve_mass(trace):
    """Rescaled counts recover the full trace's reference mass to
    within the sampling noise — on this deliberately skewed trace
    (128 hot lines carry half the mass) single-seed totals can be
    ~15% off, so every seed is checked against a cluster-level
    tolerance, not a reference-count one."""
    for seed in range(5):
        prof = sampled_reuse_profile(trace, 64, rate=0.25, seed=seed)
        assert prof.total == pytest.approx(len(trace), rel=0.25), seed


def test_sampled_cold_trace_all_infinite():
    tr = np.arange(500) * 64  # every access cold
    prof = sampled_reuse_profile(tr, 64, rate=0.5)
    assert list(prof.distances) == [-1]


# --- rate 1.0: bit-identical to the exact path -----------------------------


def test_rate_one_bit_identical(trace, exact_profile):
    prof = sampled_reuse_profile(trace, 64, rate=1.0, seed=7)
    assert np.array_equal(prof.distances, exact_profile.distances)
    assert np.array_equal(prof.counts, exact_profile.counts)
    assert prof.total == exact_profile.total
    assert prof.error_bound == 0.0


def test_rate_one_windows_bit_identical(trace, exact_profile):
    prof = sampled_profile_windows(trace, 64, rate=1.0, window_size=4096)
    assert np.array_equal(prof.distances, exact_profile.distances)
    assert np.array_equal(prof.counts, exact_profile.counts)
    assert prof.error_bound == 0.0


def test_windows_match_in_memory(trace):
    """The constant-memory windowed path produces the same profile as
    the in-memory sampled pass at every rate."""
    for rate in (0.25, 0.6):
        mem = sampled_reuse_profile(trace, 64, rate=rate, seed=2)
        win = sampled_profile_windows(trace, 64, rate=rate, seed=2,
                                      window_size=1 << 12)
        assert np.array_equal(mem.distances, win.distances)
        assert np.array_equal(mem.counts, win.counts)
        assert mem.error_bound == win.error_bound


# --- determinism per (seed, rate) ------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 20),
    rate_pct=st.integers(min_value=1, max_value=99),
)
def test_sampling_deterministic_per_seed_and_rate(seed, rate_pct):
    rng = np.random.default_rng(11)
    lines = rng.integers(0, 5000, size=4000)
    rate = rate_pct / 100.0
    m1 = sample_lines_mask(lines, rate=rate, seed=seed)
    m2 = sample_lines_mask(lines, rate=rate, seed=seed)
    assert np.array_equal(m1, m2)
    # spatial hashing: the SAME line is always kept or always dropped
    for line in np.unique(lines)[:50]:
        picks = m1[lines == line]
        assert picks.all() or not picks.any()


def test_different_seeds_sample_differently():
    lines = np.arange(20_000)
    m0 = sample_lines_mask(lines, rate=0.5, seed=0)
    m1 = sample_lines_mask(lines, rate=0.5, seed=1)
    assert not np.array_equal(m0, m1)
    # both still keep roughly the requested fraction
    for m in (m0, m1):
        assert 0.45 < m.mean() < 0.55


@settings(max_examples=20, deadline=None)
@given(rate_pct=st.integers(min_value=1, max_value=100))
def test_mask_keeps_roughly_rate_fraction(rate_pct):
    rate = rate_pct / 100.0
    lines = np.arange(50_000)
    frac = sample_lines_mask(lines, rate=rate).mean()
    assert abs(frac - rate) < 0.02, (rate, frac)


# --- the error bound itself ------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    rate_pct=st.integers(min_value=1, max_value=99),
    n=st.integers(min_value=1000, max_value=10_000_000),
)
def test_bound_monotone_in_rate_and_n(rate_pct, n):
    rate = rate_pct / 100.0
    b = sampling_error_bound(rate, n)
    assert 0.0 < b <= 1.0
    # more samples (higher rate or longer trace) never loosen the bound
    assert sampling_error_bound(min(1.0, rate * 2), n) <= b
    assert sampling_error_bound(rate, n * 2) <= b


def test_bound_zero_at_full_rate():
    assert sampling_error_bound(1.0, 1000) == 0.0


def test_bound_formula_matches_documented_closed_form():
    """The documented Bernstein closed form, spelled out once in a test
    so a silent constant change fails here AND in tools/docs_check.py:

        L = ln(2 (n+1) / delta)
        V = (1-R) ssq / (R n^2)
        bound = min(1, sqrt(2 V L) + wmax L / (3 R n))
    """
    rate, n, ssq, wmax = 0.25, 50_000, 2.0e6, 120.0
    log_term = np.log(2.0 * (n + 1) / SAMPLE_BOUND_DELTA)
    variance = (1.0 - rate) * ssq / (rate * n**2)
    expected = min(1.0, float(np.sqrt(2.0 * variance * log_term)
                              + wmax * log_term / (3.0 * rate * n)))
    got = sampling_error_bound(rate, n, sq_line_mass=ssq,
                               max_line_mass=wmax)
    assert got == pytest.approx(expected)
    # the uniform fallback is the w_l == 1 special case of the same form
    uniform = sampling_error_bound(rate, n)
    assert uniform == pytest.approx(min(1.0, float(
        np.sqrt(2.0 * (1.0 - rate) / (rate * n) * log_term)
        + log_term / (3.0 * rate * n)
    )))


def test_bound_hajek_ratio_correction():
    """With kept_refs, the bound is the Hajek ratio form
    min(1, eps n / S_hat + |n - S_hat| / S_hat): mass-balanced samples
    barely move, samples that lost most of the trace's mass (a dominant
    line dropped by the spatial filter) inflate toward 1."""
    rate, n, ssq, wmax = 0.25, 50_000, 2.0e6, 120.0
    eps = sampling_error_bound(rate, n, sq_line_mass=ssq,
                               max_line_mass=wmax)
    # mass-balanced: kept == rate * n, so S_hat == n — pure eps survives
    balanced = sampling_error_bound(rate, n, sq_line_mass=ssq,
                                    max_line_mass=wmax,
                                    kept_refs=int(rate * n))
    assert balanced == pytest.approx(eps)
    # the exact documented closed form at an imbalanced point
    kept = 5_000
    s_hat = kept / rate
    expected = min(1.0, eps * (n / s_hat) + abs(n - s_hat) / s_hat)
    got = sampling_error_bound(rate, n, sq_line_mass=ssq,
                               max_line_mass=wmax, kept_refs=kept)
    assert got == pytest.approx(expected)
    # a sample that saw almost none of the trace's mass declares ~1:
    # the dropped-hot-line regime the pure HT moments cannot see
    degenerate = sampling_error_bound(rate, n, sq_line_mass=10.0,
                                      max_line_mass=2.0, kept_refs=100)
    assert degenerate == 1.0
    # an empty sample is maximally uninformative
    assert sampling_error_bound(rate, n, kept_refs=0) == 1.0


def test_degenerate_sampled_profile_declares_honest_bound():
    """A trace dominated by one hot line whose spatial sample drops that
    line must declare a bound that covers the (large) actual deviation —
    the polybench/durbin 8-core regression."""
    rng = np.random.default_rng(7)
    n = 4096
    # one line carries ~97% of references, a handful of cold lines the rest
    hot = np.full(n, 7, dtype=np.int64)
    cold_at = rng.choice(n, size=n // 32, replace=False)
    hot[cold_at] = rng.integers(1000, 1100, size=cold_at.size)
    for seed in range(8):
        prof = sampled_reuse_profile(hot, rate=0.5, seed=seed)
        exact = profile_from_distances(reuse_distances(hot))
        # sup-norm deviation of the two profiles' CDFs at every distance
        dev = _max_cdf_deviation(exact, prof)
        assert dev <= prof.error_bound + 1e-9, (
            f"seed {seed}: deviation {dev:.4f} exceeds declared "
            f"bound {prof.error_bound:.4f}"
        )


def _max_cdf_deviation(exact, estimate):
    """max over thresholds d of |F_exact(d) - F_estimate(d)| over finite
    distances (INF_RD mass contributes via the totals)."""
    thresholds = np.unique(np.concatenate([
        exact.distances[exact.distances >= 0],
        estimate.distances[estimate.distances >= 0],
        np.array([0], dtype=exact.distances.dtype),
    ]))
    dev = 0.0
    for d in thresholds.tolist():
        fe = _cdf_at(exact, d)
        fs = _cdf_at(estimate, d)
        dev = max(dev, abs(fe - fs))
    return dev


def _cdf_at(profile, d):
    finite = profile.distances >= 0
    below = finite & (profile.distances <= d)
    return float(profile.counts[below].sum()) / float(profile.total)


@settings(max_examples=20, deadline=None)
@given(rate_pct=st.integers(min_value=1, max_value=100))
def test_rate_validation(rate_pct):
    with pytest.raises(ValueError):
        sampled_reuse_profile(np.arange(10), rate=0.0)
    with pytest.raises(ValueError):
        sampled_reuse_profile(np.arange(10), rate=rate_pct / 100.0 + 1.0)
