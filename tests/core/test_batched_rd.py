"""Batched multi-segment reuse-distance engines vs the monolithic
oracle (ISSUE-5 tentpole): segment-level bit-identity for both the
vmapped Fenwick engine and the vectorized offline engine, plus the
per-set routing satellite."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reuse import distance as distance_mod
from repro.core.reuse.batched import (
    count_leq_before,
    reuse_distances_batched,
    reuse_distances_offline,
)
from repro.core.reuse.distance import (
    INF_RD,
    per_set_reuse_distances,
    reuse_distances,
    reuse_distances_ref,
)


# --- offline engine primitives --------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=-1, max_value=50), min_size=0,
                max_size=300))
def test_count_leq_before_matches_bruteforce(values):
    p = np.asarray(values, dtype=np.int64)
    got = count_leq_before(p)
    ref = np.array(
        [int(np.sum(p[:t] <= p[t])) for t in range(p.size)], dtype=np.int64
    )
    assert np.array_equal(got, ref)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                max_size=400))
def test_offline_matches_stack_oracle(trace):
    t = np.asarray(trace, dtype=np.int64)
    assert np.array_equal(reuse_distances_offline(t),
                          reuse_distances_ref(t))


def test_reuse_distances_method_equivalence():
    rng = np.random.default_rng(0)
    t = rng.integers(0, 1 << 12, size=5000) * 16
    a = reuse_distances(t, 64, method="scan")
    b = reuse_distances(t, 64, method="offline")
    c = reuse_distances(t, 64, method="auto")
    assert np.array_equal(a, b) and np.array_equal(a, c)
    with pytest.raises(ValueError):
        reuse_distances(t, method="nope")


def test_reuse_distances_auto_threshold(monkeypatch):
    """Above the threshold, auto must route offline (same bits)."""
    monkeypatch.setattr(distance_mod, "RD_OFFLINE_THRESHOLD", 64)
    rng = np.random.default_rng(1)
    t = rng.integers(0, 40, size=500)
    assert np.array_equal(reuse_distances(t),
                          reuse_distances(t, method="scan"))


# --- batched engines: segment-level bit-identity ---------------------------


segments_strategy = st.lists(
    st.lists(st.integers(min_value=0, max_value=30), min_size=0,
             max_size=120),
    min_size=1,
    max_size=6,
)


@settings(max_examples=20, deadline=None)
@given(segments_strategy)
def test_batched_offline_bit_identical_per_segment(segments):
    segs = [np.asarray(s, dtype=np.int64) for s in segments]
    got = reuse_distances_batched(segs, engine="offline")
    for g, s in zip(got, segs):
        ref = (reuse_distances_ref(s) if s.size
               else np.empty(0, dtype=np.int64))
        assert np.array_equal(g, ref)


@settings(max_examples=12, deadline=None)
@given(segments_strategy)
def test_batched_fenwick_bit_identical_per_segment(segments):
    # window=32 forces multi-window scans + host compactions on tiny
    # segments, exercising the windowed carry logic, while keeping the
    # pow2 bucket set (and therefore XLA compile count) small
    segs = [np.asarray(s, dtype=np.int64) for s in segments]
    got = reuse_distances_batched(segs, engine="fenwick", window=32)
    for g, s in zip(got, segs):
        ref = (reuse_distances_ref(s) if s.size
               else np.empty(0, dtype=np.int64))
        assert np.array_equal(g, ref)


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=2000), min_size=1,
             max_size=500),
    st.integers(min_value=1, max_value=5),
)
def test_random_splits_match_monolithic_oracle(trace, pieces):
    """A trace split at random points: each piece's batched distances
    equal the monolithic scan of that piece alone."""
    t = np.asarray(trace, dtype=np.int64)
    cuts = np.linspace(0, t.size, pieces + 1).astype(int)
    segs = [t[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    for engine in ("offline", "fenwick"):
        got = reuse_distances_batched(segs, engine=engine, window=64)
        for g, s in zip(got, segs):
            ref = (reuse_distances(s, method="scan") if s.size
                   else np.empty(0, dtype=np.int64))
            assert np.array_equal(g, ref)


def test_batched_line_size():
    rng = np.random.default_rng(2)
    segs = [rng.integers(0, 1 << 14, size=300) * 8 for _ in range(3)]
    for engine in ("offline", "fenwick"):
        got = reuse_distances_batched(segs, line_size=64, engine=engine,
                                      window=64)
        for g, s in zip(got, segs):
            assert np.array_equal(g, reuse_distances(s, 64, method="scan"))


def test_batched_rejects_unknown_engine():
    with pytest.raises(ValueError):
        reuse_distances_batched([np.arange(4)], engine="magic")


# --- sharded passes: bit-identical merge for every shard count -------------


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=-1, max_value=60), min_size=0,
             max_size=400),
    st.integers(min_value=2, max_value=8),
)
def test_count_leq_before_sharded_bit_identical(values, shards):
    """The chunk-decomposed dominance count is an exact integer
    identity: bit-identical to the monolithic pass for every shard
    count (including shards > n)."""
    p = np.asarray(values, dtype=np.int64)
    assert np.array_equal(count_leq_before(p, num_shards=shards),
                          count_leq_before(p))


@settings(max_examples=12, deadline=None)
@given(segments_strategy, st.integers(min_value=2, max_value=5))
def test_sharded_batched_offline_bit_identical(segments, shards):
    """LPT-sharded offline pass merges back to the exact per-segment
    distances of the single-shard pass."""
    segs = [np.asarray(s, dtype=np.int64) for s in segments]
    mono = reuse_distances_batched(segs, engine="offline", num_shards=1)
    shd = reuse_distances_batched(segs, engine="offline",
                                  num_shards=shards)
    for a, b in zip(mono, shd):
        assert np.array_equal(a, b)


@settings(max_examples=8, deadline=None)
@given(segments_strategy, st.integers(min_value=2, max_value=4))
def test_sharded_batched_fenwick_bit_identical(segments, shards):
    """The sharded split composes with the windowed fenwick engine
    (compactions and carries are per-group, so the scatter-merge stays
    exact)."""
    segs = [np.asarray(s, dtype=np.int64) for s in segments]
    mono = reuse_distances_batched(segs, engine="fenwick", window=32,
                                   num_shards=1)
    shd = reuse_distances_batched(segs, engine="fenwick", window=32,
                                  num_shards=shards)
    for a, b in zip(mono, shd):
        assert np.array_equal(a, b)


def test_sharded_single_oversized_segment():
    """A lone segment can't be LPT-split; its offline dominance count
    chunk-parallelizes instead — still bit-identical."""
    rng = np.random.default_rng(6)
    t = rng.integers(0, 1 << 12, size=20_000)
    mono = reuse_distances_batched([t], engine="offline", num_shards=1)
    shd = reuse_distances_batched([t], engine="offline", num_shards=4)
    assert np.array_equal(mono[0], shd[0])
    assert np.array_equal(mono[0], reuse_distances(t, method="scan"))


def test_sharded_default_uses_local_shard_count():
    """num_shards=None routes through repro.dist.sharding and stays
    exact whatever the device count is."""
    rng = np.random.default_rng(7)
    segs = [rng.integers(0, 200, size=n) for n in (0, 37, 512, 1009)]
    auto = reuse_distances_batched(segs)
    for got, s in zip(auto, segs):
        ref = (reuse_distances(s, method="scan") if s.size
               else np.empty(0, dtype=np.int64))
        assert np.array_equal(got, ref)


def test_sharded_mixed_empty_segments():
    """Empty segments are filled eagerly and never reach the shard
    partition; ordering of results still matches the input."""
    rng = np.random.default_rng(8)
    segs = [np.empty(0, dtype=np.int64), rng.integers(0, 50, size=200),
            np.empty(0, dtype=np.int64), rng.integers(0, 50, size=300)]
    got = reuse_distances_batched(segs, engine="offline", num_shards=3)
    assert got[0].size == 0 and got[2].size == 0
    assert np.array_equal(got[1], reuse_distances(segs[1], method="scan"))
    assert np.array_equal(got[3], reuse_distances(segs[3], method="scan"))


# --- per-set routing satellite --------------------------------------------


@pytest.mark.parametrize("num_sets", [1, 2, 8, 64])
def test_per_set_batched_equals_monolithic(num_sets):
    rng = np.random.default_rng(3)
    t = rng.integers(0, 1 << 16, size=4000)
    mono = per_set_reuse_distances(t, line_size=64, num_sets=num_sets,
                                   method="monolithic")
    bat = per_set_reuse_distances(t, line_size=64, num_sets=num_sets,
                                  method="batched")
    assert np.array_equal(mono, bat)


def test_per_set_auto_threshold(monkeypatch):
    """Auto routing must kick in above the threshold and stay exact."""
    monkeypatch.setattr(distance_mod, "PER_SET_BATCH_THRESHOLD", 256)
    rng = np.random.default_rng(4)
    t = rng.integers(0, 1 << 14, size=2000)
    mono = per_set_reuse_distances(t, line_size=64, num_sets=16,
                                   method="monolithic")
    auto = per_set_reuse_distances(t, line_size=64, num_sets=16)
    assert np.array_equal(mono, auto)


def test_per_set_hit_semantics_preserved():
    """The paper's per-set hit rule on the batched path: distances <
    associativity are hits, first touches (INF_RD) are not."""
    rng = np.random.default_rng(5)
    t = rng.integers(0, 1 << 10, size=1000) * 64
    rds = per_set_reuse_distances(t, line_size=64, num_sets=4,
                                  method="batched")
    assert (rds >= INF_RD).all()
    assert (rds == INF_RD).sum() == len(np.unique(t // 64))
