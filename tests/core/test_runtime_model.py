"""Runtime model (Eq. 4-7) hand-computed checks."""
import math

import pytest

from repro.core.runtime_model import (
    OpCounts,
    cumulative_to_conditional,
    effective_beta_cy,
    effective_latency_cy,
    level_chain,
    noncontiguous_block_size,
    predict_runtime_s,
    t_cpu_s,
    t_mem_s,
)
from repro.hw.targets import HASWELL_I7_5960X as HW


def test_eq6_hand_computed():
    # delta_avg = P1 d1 + (1-P1)[P2 d2 + (1-P2)[P3 d3 + (1-P3) dram]]
    p = [0.9, 0.8, 0.5]
    d = list(HW.level_latency_cy)
    dram = HW.ram_latency_cy
    expected = p[0] * d[0] + (1 - p[0]) * (
        p[1] * d[1] + (1 - p[1]) * (p[2] * d[2] + (1 - p[2]) * dram)
    )
    assert abs(effective_latency_cy(HW, p) - expected) < 1e-12


def test_eq6_limits():
    assert effective_latency_cy(HW, [1.0, 0.0, 0.0]) == HW.level_latency_cy[0]
    assert effective_latency_cy(HW, [0.0, 0.0, 0.0]) == HW.ram_latency_cy


def test_eq7_uses_betas():
    p = [0.5, 0.5, 0.5]
    assert effective_beta_cy(HW, p) < effective_latency_cy(HW, p)


def test_eq5_block_amortization():
    """Larger blocks amortize the latency term: per-byte cost falls."""
    rates = [0.9, 0.8, 0.5]
    t_small = t_mem_s(HW, rates, 1e6, block_bytes=8)
    t_large = t_mem_s(HW, rates, 1e6, block_bytes=64)
    assert t_large < t_small


def test_noncontiguous_clamps():
    assert noncontiguous_block_size(10, 64, 4096) == 64          # <= C -> C
    assert noncontiguous_block_size(100, 64, 4096) == 128        # ceil to C
    assert noncontiguous_block_size(10_000, 64, 4096) == 4096    # >= S -> S


def test_noncontiguous_quantization_never_overshoots_cap():
    """Regression: with C not dividing S, ceil-to-chunk of a block just
    under the cap used to return a block LARGER than the cap
    (b_new=99, C=64, S=100 -> 128)."""
    assert noncontiguous_block_size(99, 64, 100) == 100
    # sweep: the invariant holds everywhere, not just at the example
    for b_new in range(1, 300):
        b = noncontiguous_block_size(float(b_new), 64, 100)
        assert 64 <= b <= 100


def test_gap_increases_block():
    rates = [0.9, 0.8, 0.5]
    t0 = t_mem_s(HW, rates, 1e6)
    t1 = t_mem_s(HW, rates, 1e6, gap_bytes=24.0)
    assert t1 != t0  # non-contiguous model engaged


def test_tcpu_modes():
    c = OpCounts(int_ops=1000, fp_ops=500, div_ops=10)
    thr = t_cpu_s(HW, c, "throughput")
    lat = t_cpu_s(HW, c, "latency")
    # latency-bound chain is slower than pipelined issue
    assert lat > thr > 0
    i = HW.instr
    expected_thr_cy = (
        (i.delta_int + 999 * i.beta_int)
        + (i.delta_fp + 499 * i.beta_fp)
        + (i.delta_div + 9 * i.beta_div)
    )
    assert abs(thr - expected_thr_cy * HW.cycle_s) < 1e-15


def test_predict_runtime_divides_work():
    c = OpCounts(int_ops=8000, fp_ops=8000, div_ops=0, total_bytes=1e6)
    r1 = predict_runtime_s(HW, [0.9, 0.8, 0.5], c, 1)
    r8 = predict_runtime_s(HW, [0.9, 0.8, 0.5], c, 8)
    assert r8["t_pred_s"] < r1["t_pred_s"]
    assert abs(r1["t_mem_s"] / 8 - r8["t_mem_s"]) / r8["t_mem_s"] < 1e-9


def test_cumulative_to_conditional():
    cond = cumulative_to_conditional([0.5, 0.75, 1.0])
    assert abs(cond[0] - 0.5) < 1e-12
    assert abs(cond[1] - 0.5) < 1e-12  # (0.75-0.5)/0.5
    assert abs(cond[2] - 1.0) < 1e-12


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        t_cpu_s(HW, OpCounts(int_ops=1), mode="warp")


def test_level_chain_length_mismatch_raises():
    """Regression: zip used to truncate silently, so a 2-level rate
    list against a 3-level target dropped the deepest level's cost."""
    with pytest.raises(ValueError, match="one hit rate per level"):
        level_chain([4.0, 12.0, 36.0], [0.9, 0.8], 240.0)
    with pytest.raises(ValueError, match="one hit rate per level"):
        effective_latency_cy(HW, [0.9, 0.8])
    with pytest.raises(ValueError, match="one hit rate per level"):
        effective_beta_cy(HW, [0.9, 0.8, 0.5, 0.1])


def test_level_chain_empty_is_final_term():
    assert level_chain([], [], 240.0) == 240.0


# --- cumulative_to_conditional edge cases ------------------------------------


def test_cumulative_to_conditional_exact_zero_and_one():
    # nothing served anywhere until a final level that serves all
    assert cumulative_to_conditional([0.0, 0.0, 1.0]) == pytest.approx(
        [0.0, 0.0, 1.0])
    # everything served at L1: downstream levels see no traffic, and
    # their conditional rate is the 1.0 convention (miss_prob ~ 0)
    assert cumulative_to_conditional([1.0, 1.0, 1.0]) == pytest.approx(
        [1.0, 1.0, 1.0])
    assert cumulative_to_conditional([0.0]) == pytest.approx([0.0])
    assert cumulative_to_conditional([1.0]) == pytest.approx([1.0])


def test_cumulative_to_conditional_nonmonotone_clamps():
    """A dip in the cumulative sequence cannot mint negative service:
    the conditional rate floors at 0 and downstream levels keep their
    own (valid) conditional rates."""
    cond = cumulative_to_conditional([0.9, 0.5, 0.95])
    assert cond[0] == pytest.approx(0.9)
    assert cond[1] == 0.0            # 0.5 < 0.9 -> nothing served here
    assert 0.0 <= cond[2] <= 1.0
    # and never out of range for any input
    for cum in ([0.7, 0.2, 0.4], [1.0, 0.3, 0.9], [0.2, 1.0, 0.5]):
        for c in cumulative_to_conditional(cum):
            assert 0.0 <= c <= 1.0


def test_cumulative_to_conditional_roundtrip_with_level_chain():
    """Conditional rates reconstruct the cumulative sequence
    (C_i = 1 - prod(1-c_j)), and the conditional chain equals the
    explicit served-fraction sum over levels."""
    cum = [0.5, 0.75, 0.9]
    cond = cumulative_to_conditional(cum)
    reach = 1.0
    rebuilt = []
    for c in cond:
        reach *= (1.0 - c)
        rebuilt.append(1.0 - reach)
    assert rebuilt == pytest.approx(cum)

    values = list(HW.level_latency_cy)
    final = HW.ram_latency_cy
    # explicit expansion: sum of (fraction served at level i) * v_i
    reach = 1.0
    expected = 0.0
    for c, v in zip(cond, values):
        expected += reach * c * v
        reach *= (1.0 - c)
    expected += reach * final
    assert level_chain(values, cond, final) == pytest.approx(expected)
