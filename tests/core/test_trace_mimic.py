"""Algorithm 1 (private trace mimicking) semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trace.mimic import choose_offset, core_assignment, gen_private_traces
from repro.core.trace.types import LabeledTrace, trace_from_blocks


def toy_trace(num_iters=8, shared_addr=1000):
    blocks = [("entry", np.array([shared_addr, shared_addr + 8]), True)]
    for i in range(num_iters):
        blocks.append(
            (
                "for.body",
                np.array([2000 + 8 * i, shared_addr]),
                np.array([False, True]),
            )
        )
    return trace_from_blocks(blocks)


def test_single_instance_blocks_replicated():
    tr = toy_trace(8)
    privs = gen_private_traces(tr, 4)
    for p in privs:
        # entry block (1 instance < 4 cores) present on every core
        names = {p.bb_names[b] for b in np.unique(p.bb_ids)}
        assert "entry" in names
        assert len(p) == 2 + 2 * 2  # entry + 8/4 loop instances x 2 refs


def test_loop_instances_split_evenly():
    tr = toy_trace(16)
    _, core = core_assignment(tr, 4)
    body_mask = tr.bb_ids == 1
    counts = np.bincount(core[body_mask], minlength=4)
    assert (counts == counts[0]).all()


def test_offsets_distinct_and_shared_preserved():
    tr = toy_trace(8, shared_addr=1000)
    privs = gen_private_traces(tr, 4)
    for c, p in enumerate(privs):
        shared_addrs = set(p.addresses[p.shared_mask].tolist())
        assert shared_addrs == {1000, 1008}  # shared refs never offset
        priv_addrs = set(p.addresses[~p.shared_mask].tolist())
        for c2 in range(c):
            other = set(
                privs[c2].addresses[~privs[c2].shared_mask].tolist()
            )
            assert not (priv_addrs & other), "private refs must not collide"


def test_master_core_keeps_original_addresses():
    tr = toy_trace(8)
    privs = gen_private_traces(tr, 4)
    assert set(privs[0].addresses.tolist()) <= set(tr.addresses.tolist())


def test_one_core_is_identity():
    tr = toy_trace(8)
    (only,) = gen_private_traces(tr, 1)
    assert np.array_equal(only.addresses, tr.addresses)


def test_chunked_assignment():
    tr = toy_trace(16)
    _, core = core_assignment(tr, 4, chunk_size=2)
    body_inst = tr.instance_index()[tr.bb_ids == 1]
    expected = (body_inst // 2) % 4
    assert np.array_equal(core[tr.bb_ids == 1], expected)


def test_remainder_instances_clamped_to_last_core():
    tr = toy_trace(10)  # 10 instances over 4 cores -> per_core=2, inst 8,9 -> core 3
    _, core = core_assignment(tr, 4)
    assert core[tr.bb_ids == 1].max() == 3


def test_choose_offset_exceeds_footprint():
    addrs = np.array([0, 100, 5000])
    off = choose_offset(addrs)
    assert off > 5000 and off % 4096 == 0


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),
    st.integers(min_value=1, max_value=40),
)
def test_reference_conservation(num_cores, num_iters):
    """Every original reference lands on >= 1 core; split blocks' refs
    appear exactly once across cores; replicated blocks appear num_cores
    times."""
    tr = toy_trace(num_iters)
    privs = gen_private_traces(tr, num_cores)
    total = sum(len(p) for p in privs)
    n_entry = 2
    n_body = 2 * num_iters
    if num_iters < num_cores:
        assert total == num_cores * (n_entry + n_body)
    else:
        assert total == num_cores * n_entry + n_body
