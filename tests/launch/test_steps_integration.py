"""Integration: the dry-run cell builders lower + compile on a small
multi-device mesh (subprocess — device count must precede jax init).

This is the same machinery the 512-device production dry-run uses,
exercised at 2x4 with reduced configs so it runs in CI time.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT_TEMPLATE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
from repro.configs.base import Shape
from repro.configs.reduced import reduced_arch
from repro.launch.steps import build_cell, lower_cell
from repro.analysis.hlo_cost import loop_aware_cost

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
spec = reduced_arch("{arch}")
shape = Shape("t", {seq}, 8, "{kind}")
cell = build_cell(spec, shape, mesh)
compiled = lower_cell(cell).compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
cost = loop_aware_cost(compiled.as_text())
assert cost["flops"] > 0, cost
print("CELL-OK", int(cost["flops"]), int(cost["ici_bytes"]))
"""

CASES = [
    ("llama3-8b", 64, "train"),
    ("mixtral-8x7b", 64, "train"),
    ("mamba2-780m", 64, "train"),
    ("zamba2-1.2b", 64, "prefill"),
    ("seamless-m4t-medium", 64, "train"),
    ("phi-3-vision-4.2b", 32, "decode"),
]


@pytest.mark.parametrize("arch,seq,kind", CASES)
def test_cell_lowers_and_compiles_on_2x4(arch, seq, kind):
    repo = Path(__file__).resolve().parents[2]
    script = SCRIPT_TEMPLATE.format(arch=arch, seq=seq, kind=kind)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600, cwd=repo,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # without this jax probes accelerator plugins for minutes
             **({"JAX_PLATFORMS": os.environ["JAX_PLATFORMS"]}
                if "JAX_PLATFORMS" in os.environ else {})},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "CELL-OK" in proc.stdout
