"""Fused config sweep vs the per-config oracle paths.

The acceptance bar: sweep hit rates are BIT-identical to
`batched_hit_rates` evaluating each candidate target row-by-row, the
on-device ECM chain matches the host `ECMRuntimeModel`, and the Pallas
inner evaluator agrees with the vmap inner to 1e-6.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import Session
from repro.api.batched import batched_hit_rates, compile_count
from repro.api.stages import shared_level_index
from repro.core.incore import ECMRuntimeModel
from repro.core.runtime_model import OpCounts
from repro.core.trace.types import trace_from_blocks
from repro.explore import FusedSweepEvaluator, SearchSpace
from repro.hw.targets import resolve_target

COUNTS = OpCounts(int_ops=3000, fp_ops=1500, div_ops=10, loads=3000,
                  stores=1500, total_bytes=4500 * 8)

SPACE = SearchSpace(
    sets=(512, 4096), ways=(4, 8), latency_cy=(20.0, 36.0),
    cores=(1, 2), strategies=("round_robin",),
)


def small_trace(iters=600, stride=8):
    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


@pytest.fixture(scope="module")
def sweep_setup():
    source = small_trace()
    session = Session(cache_model="batched")
    evaluator = FusedSweepEvaluator(
        source, SPACE, session=session, counts=COUNTS,
    )
    return source, session, evaluator


def oracle_items(session, source, evaluator, configs):
    """The sequential path: one applied target + artifact set per
    candidate, exactly what `Session.predict` would evaluate."""
    base = evaluator.base
    li = evaluator.level_idx
    items = []
    for cfg in configs:
        art = session.artifacts(
            source, cfg.cores, strategy=cfg.strategy, seed=0,
            line_size=cfg.line_size,
        )
        items.append((cfg.apply(base, li), art))
    return items


def test_sweep_rates_bit_identical_to_batched_hit_rates(sweep_setup):
    source, session, evaluator = sweep_setup
    configs = SPACE.configs()
    assert len(configs) >= 8
    res = evaluator.evaluate(configs)

    items = oracle_items(session, source, evaluator, configs)
    oracle = batched_hit_rates(items)
    level_names = [lvl.name for lvl in evaluator.base.levels]
    for ci, per_level in enumerate(oracle):
        want = np.array([per_level[n] for n in level_names])
        got = res.rates[ci]
        assert got.tolist() == want.tolist(), (
            f"config {configs[ci]} rates diverge from the oracle"
        )


def test_sweep_runtime_matches_host_ecm(sweep_setup):
    source, session, evaluator = sweep_setup
    configs = SPACE.configs()
    res = evaluator.evaluate(configs)
    assert res.t_pred_s is not None and np.all(res.t_pred_s > 0)

    model = ECMRuntimeModel()
    items = oracle_items(session, source, evaluator, configs)
    for ci, ((target, _art), per_level) in enumerate(
        zip(items, batched_hit_rates(items))
    ):
        host = model.runtime(
            target, per_level, COUNTS, configs[ci].cores,
            mode="throughput",
        )["t_pred_s"]
        # traced scalars ride as f32 0-d arrays; ~1e-7 rel agreement
        assert res.t_pred_s[ci] == pytest.approx(host, rel=1e-5)


def test_pallas_inner_matches_vmap_inner(sweep_setup):
    source, session, evaluator = sweep_setup
    configs = SPACE.configs()[:6]
    vmap_res = evaluator.evaluate(configs)
    pallas_eval = FusedSweepEvaluator(
        source, SPACE, session=session, counts=COUNTS, inner="pallas",
    )
    pallas_res = pallas_eval.evaluate(configs)
    assert np.max(np.abs(pallas_res.rates - vmap_res.rates)) <= 1e-6
    assert pallas_res.t_pred_s == pytest.approx(
        vmap_res.t_pred_s, rel=1e-5
    )


def test_llc_miss_objective_without_counts(sweep_setup):
    source, session, _evaluator = sweep_setup
    ev = FusedSweepEvaluator(source, SPACE, session=session,
                             objective="llc_miss")
    configs = SPACE.configs()[:4]
    res = ev.evaluate(configs)
    assert res.t_pred_s is None
    assert np.allclose(res.scores, 1.0 - res.rates[:, -1])
    # a raw trace has no op counts: runtime objective must refuse
    with pytest.raises(ValueError, match="op counts"):
        FusedSweepEvaluator(source, SPACE, session=session,
                            objective="runtime")


def test_repeat_sweeps_compile_nothing_new(sweep_setup):
    source, session, evaluator = sweep_setup
    configs = SPACE.configs()
    evaluator.evaluate(configs)  # warm the compile caches
    before = compile_count()
    res = evaluator.evaluate(configs)
    assert compile_count() == before
    assert res.dispatches if hasattr(res, "dispatches") else True
    # profile packs are cached per (line, cores, strategy) group
    groups = {(c.line_size, c.cores, c.strategy) for c in configs}
    assert evaluator.stats.profile_groups == len(groups)


def test_sweep_geometry_matches_applied_targets(sweep_setup):
    """The staged geometry IS the applied target's geometry — the
    invariant the bit-identity test rests on."""
    _source, _session, evaluator = sweep_setup
    base = resolve_target(SPACE.target)
    li = evaluator.level_idx
    cfgs = [c for c in SPACE.configs() if c.cores == 1][:4]
    geom = evaluator._geometry(cfgs, 64, 1)
    for ci, cfg in enumerate(cfgs):
        tgt = cfg.apply(base, li)
        for lv, lvl in enumerate(tgt.levels):
            assert geom.assoc[ci, lv] == lvl.effective_assoc
            assert geom.blocks[ci, lv] == lvl.num_lines
    assert shared_level_index(base) == evaluator.shared_idx
