"""Search agents on a seeded synthetic landscape (no device work)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.explore import SearchSpace, make_agent
from repro.explore.agents import AGENTS, ScoreCache, Trajectory

SPACE = SearchSpace(
    sets=(256, 1024, 4096, 16384),
    ways=(2, 4, 8),
    latency_cy=(20.0, 36.0, 60.0),
    cores=(1, 2),
)


def landscape(configs):
    """Deterministic smooth fitness with one global optimum: the
    4096x8w config at the lowest latency on 2 cores."""
    out = []
    for c in configs:
        out.append(
            abs(np.log2(c.sets * c.ways) - np.log2(4096 * 8))
            + 0.01 * c.latency_cy
            + (0.5 if c.cores == 1 else 0.0)
        )
    return np.asarray(out)


def best_score():
    pool = SPACE.configs()
    return float(np.min(landscape(pool)))


@pytest.mark.parametrize("name", sorted(AGENTS))
def test_agents_recover_known_best_on_seeded_landscape(name):
    agent = make_agent(name)
    traj = Trajectory(agent=name, seed=3)
    cache = ScoreCache(landscape, budget=SPACE.size, trajectory=traj)
    agent.search(SPACE, cache, np.random.default_rng(3))
    assert traj.best_score == pytest.approx(best_score())
    assert traj.best_config is not None
    assert traj.evaluations <= SPACE.size
    assert traj.rounds and all("tag" in r for r in traj.rounds)


@pytest.mark.parametrize("name", sorted(AGENTS))
def test_agents_are_deterministic_per_seed(name):
    def run(seed):
        traj = Trajectory(agent=name, seed=seed)
        cache = ScoreCache(landscape, budget=40, trajectory=traj)
        make_agent(name).search(SPACE, cache, np.random.default_rng(seed))
        return traj.to_json()

    assert run(7) == run(7)


def test_score_cache_budget_and_dedup():
    calls = []

    def counted(configs):
        calls.append(len(configs))
        return landscape(configs)

    pool = SPACE.configs()
    traj = Trajectory(agent="x", seed=0)
    cache = ScoreCache(counted, budget=5, trajectory=traj)
    # duplicates inside one proposal and across rounds never re-evaluate
    got = cache.score([pool[0], pool[0], pool[1]], tag="a")
    assert len(got) == 2 and calls == [2]
    cache.score([pool[0], pool[2]], tag="b")
    assert calls == [2, 1] and traj.evaluations == 3
    # the budget truncates, then exhausts
    cache.score(pool[3:10], tag="c")
    assert traj.evaluations == 5 and cache.exhausted
    cache.score(pool[10:12], tag="d")
    assert traj.evaluations == 5
    assert [r["evaluated"] for r in traj.rounds] == [2, 1, 2, 0]
    # top-k is sorted ascending (smaller is better)
    top = cache.top(3)
    assert [s for _k, s in top] == sorted(s for _k, s in top)


def test_make_agent_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown agent"):
        make_agent("anneal")
