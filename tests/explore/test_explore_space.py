"""SearchSpace: enumeration, constraints, serialization, apply()."""
from __future__ import annotations

import pytest

from repro.api.batched import A_MAX_LIMIT
from repro.explore import INTERLEAVE_STRATEGIES, CandidateConfig, SearchSpace
from repro.hw.targets import resolve_target


def test_default_space_enumerates_valid_unique_configs():
    space = SearchSpace()
    cfgs = space.configs()
    assert cfgs and len(cfgs) == space.size
    assert len({c.key() for c in cfgs}) == len(cfgs)
    for c in cfgs:
        assert c.ways <= c.sets
        assert c.size_bytes == c.sets * c.ways * c.line_size
    # empty latency/beta axes filled from the base target
    base = resolve_target(space.target)
    li = space.level_index(base)
    assert space.latency_cy == (float(base.level_latency_cy[li]),)
    assert space.beta_cy == (float(base.level_beta_cy[li]),)


def test_ways_gt_sets_and_size_bounds_reject_configs():
    space = SearchSpace(sets=(2, 4096), ways=(4, 8))
    for c in space.configs():
        assert c.ways <= c.sets
    bounded = SearchSpace(
        sets=(1024, 4096, 16384), ways=(4, 8, 16), line_sizes=(64,),
        min_size_bytes=1 << 20, max_size_bytes=4 << 20,
    )
    for c in bounded.configs():
        assert 1 << 20 <= c.size_bytes <= 4 << 20
    assert bounded.size < SearchSpace().size


def test_single_core_canonicalizes_strategy_axis():
    """cores == 1 has nothing to interleave: all strategies alias one
    config, so the enumeration dedups them."""
    space = SearchSpace(cores=(1,), strategies=("round_robin", "chunked"))
    assert {c.strategy for c in space.configs()} == {"round_robin"}
    multi = SearchSpace(cores=(1, 2), strategies=("round_robin", "chunked"))
    strategies = {c.strategy for c in multi.configs() if c.cores == 2}
    assert strategies == {"round_robin", "chunked"}


@pytest.mark.parametrize("bad", [
    {"sets": ()},
    {"ways": (0,)},
    {"ways": (A_MAX_LIMIT * 2,)},
    {"strategies": ("banded",)},
    {"cores": (10_000,)},
    {"target": "not-a-target"},
    {"level": "L9"},
    {"sets": (4,), "ways": (8,)},           # constraints kill everything
])
def test_invalid_spaces_raise(bad):
    with pytest.raises((ValueError, KeyError)):
        SearchSpace(**bad)


def test_json_roundtrip_and_unknown_keys():
    space = SearchSpace(sets=(512, 2048), ways=(4, 8), cores=(1, 2),
                        max_size_bytes=8 << 20)
    back = SearchSpace.from_json(space.to_json())
    assert back == space
    with pytest.raises(ValueError, match="unknown search-space keys"):
        SearchSpace.from_json({"sets": [512], "cache_sets": [1]})
    with pytest.raises(ValueError):
        SearchSpace.from_json([1, 2, 3])


def test_apply_substitutes_only_the_swept_level():
    base = resolve_target("i7-5960X")
    space = SearchSpace(level="L3")
    li = space.level_index(base)
    cfg = CandidateConfig(sets=4096, ways=8, line_size=64,
                          latency_cy=40.0, beta_cy=2.0,
                          cores=2, strategy="round_robin")
    tgt = cfg.apply(base, li)
    assert tgt.levels[li].size_bytes == cfg.size_bytes
    assert tgt.levels[li].assoc == cfg.ways
    assert tgt.level_latency_cy[li] == 40.0
    assert tgt.level_beta_cy[li] == 2.0
    for lj, lvl in enumerate(tgt.levels):
        assert lvl.line_size == 64
        if lj != li:
            assert lvl.size_bytes == base.levels[lj].size_bytes
            assert lvl.assoc == base.levels[lj].assoc
            assert tgt.level_latency_cy[lj] == base.level_latency_cy[lj]
    assert tgt.name != base.name


def test_strategy_axis_covers_known_interleaves():
    assert set(INTERLEAVE_STRATEGIES) == {
        "round_robin", "chunked", "uniform"
    }
    SearchSpace(cores=(1, 2), strategies=INTERLEAVE_STRATEGIES)
