"""run_explore: result schema, trajectory persistence, warm re-runs."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import Session
from repro.explore import SearchSpace, explore_key, run_explore

SPACE = SearchSpace(
    sets=(512, 4096), ways=(4, 8), latency_cy=(20.0, 36.0),
    cores=(1, 2),
)


def small_trace(iters=400, stride=8):
    from repro.core.trace.types import trace_from_blocks

    blocks = [("OUT__1__.entry", np.array([0, 8]), True)]
    A0, B0 = 1 << 20, 2 << 20
    for i in range(iters):
        blocks.append((
            "OUT__1__.for.body",
            np.array([A0 + stride * i, B0 + stride * (i % 64), 0]),
            np.array([False, False, True]),
        ))
    return trace_from_blocks(blocks)


def test_result_schema_and_store_roundtrip(tmp_path):
    source = small_trace()
    session = Session(cache_model="batched", artifact_dir=str(tmp_path))
    res = run_explore(source, SPACE, agent="random", budget=8, seed=1,
                      session=session, workload="unit/test")
    assert res["cached"] is False
    assert res["workload"] == "unit/test"
    assert res["space"] == SPACE.to_json()
    assert res["best"]["config"]["size_bytes"] > 0
    assert res["best"]["score"] == res["trajectory"]["best_score"]
    assert res["trajectory"]["evaluations"] <= 8
    assert res["stats"]["fused_dispatches"] >= 1
    assert len(res["top"]) >= 1
    scores = [t["score"] for t in res["top"]]
    assert scores == sorted(scores)
    assert session.store.get_json("explore", res["key"]) is not None


def test_warm_rerun_recomputes_nothing(tmp_path):
    source = small_trace()
    kwargs = dict(agent="hillclimb", budget=10, seed=2, workload="unit/test")
    cold = Session(cache_model="batched", artifact_dir=str(tmp_path))
    first = run_explore(source, SPACE, session=cold, **kwargs)
    assert first["cached"] is False

    warm = Session(cache_model="batched", artifact_dir=str(tmp_path))
    again = run_explore(small_trace(), SPACE, session=warm, **kwargs)
    assert again["cached"] is True
    assert again["key"] == first["key"]
    assert again["best"] == first["best"]
    assert again["trajectory"] == first["trajectory"]
    # the whole search came from the store: no profiles, no reuse
    # distances, no kernel compiles
    assert warm.stats.profile_builds == 0
    assert warm.stats.rd_builds == 0
    assert warm.stats.kernel_compiles == 0

    # a different budget is a different key -> a fresh search
    other = run_explore(small_trace(), SPACE, session=warm, agent="hillclimb",
                        budget=11, seed=2, workload="unit/test")
    assert other["cached"] is False


def test_refresh_bypasses_the_store(tmp_path):
    source = small_trace()
    session = Session(cache_model="batched", artifact_dir=str(tmp_path))
    kwargs = dict(agent="random", budget=6, seed=0, workload="unit/test")
    run_explore(source, SPACE, session=session, **kwargs)
    res = run_explore(source, SPACE, session=session, refresh=True, **kwargs)
    assert res["cached"] is False


def test_explore_key_is_stable_and_sensitive():
    base = ("fp", SPACE, "random", {"batch_size": 64}, 16, 0,
            "llc_miss", "throughput", "vmap")
    k = explore_key(*base)
    assert k == explore_key(*base)
    assert k != explore_key("fp2", *base[1:])
    assert k != explore_key(*base[:4], 17, *base[5:])


def test_storeless_session_still_searches():
    res = run_explore(small_trace(), SPACE, agent="random", budget=4,
                      seed=0, session=Session(cache_model="batched"))
    assert res["cached"] is False
    assert res["trajectory"]["evaluations"] <= 4


def test_agent_params_join_the_key_and_result():
    res = run_explore(
        small_trace(), SPACE, agent="ga",
        agent_params={"population": 6, "elite": 2}, budget=12, seed=4,
        session=Session(cache_model="batched"),
    )
    assert res["agent"] == "ga"
    assert res["agent_params"]["population"] == 6
    with pytest.raises(TypeError):
        run_explore(small_trace(), SPACE, agent="ga",
                    agent_params={"swarm": 1}, budget=4,
                    session=Session(cache_model="batched"))
