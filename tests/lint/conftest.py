import textwrap

import pytest

from repro.lint.engine import lint_paths


@pytest.fixture
def lint_source(tmp_path):
    """Lint a dedented source snippet as a standalone module and return
    the LintResult."""

    def run(source: str, name: str = "snippet.py"):
        path = tmp_path / name
        path.write_text(textwrap.dedent(source))
        return lint_paths([path], root=tmp_path)

    return run


@pytest.fixture
def rule_ids(lint_source):
    """Lint a snippet and return just the sorted rule IDs found."""

    def run(source: str, name: str = "snippet.py"):
        return sorted(f.rule_id for f in lint_source(source, name).findings)

    return run
