"""Engine plumbing (suppressions, fingerprints, baseline) and the CLI
exit-code contract."""
import json
import textwrap

import pytest

from repro.lint.baseline import apply_baseline, load_baseline, write_baseline
from repro.lint.cli import main
from repro.lint.engine import lint_paths
from repro.lint.rules import RULES, SEVERITIES, rules_by_family

JP_BAD = """
    import jax

    @jax.jit
    def f(x):
        return float(x)
"""

# one seeded regression per analyzer family (acceptance criterion:
# introducing any of these must make --check exit non-zero)
FAMILY_REGRESSIONS = {
    "JP": JP_BAD,
    "DN": """
        import jax

        @jax.jit
        def step(tree, xs):
            return tree + xs

        def drive(tree, xs):
            tree = step(tree, xs)
            return tree
    """,
    "CC": """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._flag = False

            def set(self):
                with self._lock:
                    self._flag = True

            def clear(self):
                self._flag = False
    """,
    "CK": """
        def cell_key(tid, seed):
            return f"{tid}"
    """,
}


def _write(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return p


# -- rule registry -------------------------------------------------------------

def test_registry_shape():
    assert len(RULES) >= 12
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.severity in SEVERITIES
        assert rule.summary and rule.fix_hint
    fams = rules_by_family()
    assert set(fams) == {"JP", "DN", "CC", "CK"}


# -- suppressions --------------------------------------------------------------

def test_same_line_suppression(lint_source):
    res = lint_source("""
        import jax

        @jax.jit
        def f(x):
            return float(x)  # repro-lint: disable=JP102 -- test fixture
    """)
    assert res.findings == []
    assert res.suppressed == 1


def test_comment_above_suppression(lint_source):
    res = lint_source("""
        import jax

        @jax.jit
        def f(x):
            # repro-lint: disable=JP102 -- sync is intentional here
            return float(x)
    """)
    assert res.findings == []
    assert res.suppressed == 1


def test_family_prefix_suppression(lint_source):
    res = lint_source("""
        import jax

        @jax.jit
        def f(x):
            return float(x)  # repro-lint: disable=JP
    """)
    assert res.findings == []


def test_file_wide_suppression(lint_source):
    res = lint_source("""
        # repro-lint: disable-file=JP102 -- generated fixture
        import jax

        @jax.jit
        def f(x):
            return float(x)

        @jax.jit
        def g(x):
            return float(x)
    """)
    assert res.findings == []
    assert res.suppressed == 2


def test_unrelated_suppression_does_not_hide(lint_source):
    res = lint_source("""
        import jax

        @jax.jit
        def f(x):
            return float(x)  # repro-lint: disable=CK401
    """)
    assert [f.rule_id for f in res.findings] == ["JP102"]


# -- fingerprints / baseline ---------------------------------------------------

def test_fingerprint_stable_across_line_drift(tmp_path):
    p = _write(tmp_path, JP_BAD)
    before = lint_paths([p], root=tmp_path).findings
    p.write_text("# a new leading comment\n# another\n"
                 + textwrap.dedent(JP_BAD))
    after = lint_paths([p], root=tmp_path).findings
    assert before[0].line != after[0].line
    assert before[0].fingerprint() == after[0].fingerprint()


def test_baseline_round_trip(tmp_path):
    p = _write(tmp_path, JP_BAD)
    findings = lint_paths([p], root=tmp_path).findings
    assert findings
    bl = tmp_path / "baseline.json"
    write_baseline(bl, findings)
    entries = load_baseline(bl)
    diff = apply_baseline(findings, entries)
    assert diff.new == [] and len(diff.accepted) == len(findings)


def test_baseline_flags_new_and_stale(tmp_path):
    p = _write(tmp_path, JP_BAD)
    findings = lint_paths([p], root=tmp_path).findings
    bl = tmp_path / "baseline.json"
    write_baseline(bl, findings)
    p.write_text(textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            return int(x)
    """))
    fresh = lint_paths([p], root=tmp_path).findings
    diff = apply_baseline(fresh, load_baseline(bl))
    assert len(diff.new) == 1          # int(x) is a new line
    assert len(diff.stale) == 1        # float(x) entry no longer matches


def test_bad_baseline_version_rejected(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"version": 99, "entries": {}}))
    with pytest.raises(ValueError):
        load_baseline(bl)


# -- CLI contract --------------------------------------------------------------

def test_cli_clean_exits_zero(tmp_path, capsys):
    _write(tmp_path, "x = 1\n")
    assert main([str(tmp_path)]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


@pytest.mark.parametrize("family", sorted(FAMILY_REGRESSIONS))
def test_cli_seeded_regression_fails(tmp_path, family, capsys):
    _write(tmp_path, FAMILY_REGRESSIONS[family])
    rc = main(["--check", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert family in out  # the family's rule ID is reported


def test_cli_baseline_check_flow(tmp_path, capsys):
    _write(tmp_path, JP_BAD)
    bl = tmp_path / "bl.json"
    assert main(["--write-baseline", "--baseline", str(bl),
                 str(tmp_path)]) == 0
    assert main(["--check", "--baseline", str(bl), str(tmp_path)]) == 0
    _write(tmp_path, FAMILY_REGRESSIONS["CK"], name="other.py")
    assert main(["--check", "--baseline", str(bl), str(tmp_path)]) == 1
    capsys.readouterr()


def test_cli_report_only_always_zero(tmp_path, capsys):
    _write(tmp_path, JP_BAD)
    assert main(["--report-only", str(tmp_path)]) == 0
    assert "JP102" in capsys.readouterr().out


def test_cli_json_output(tmp_path, capsys):
    _write(tmp_path, JP_BAD)
    rc = main(["--json", str(tmp_path)])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["files_checked"] == 1
    assert payload["new_findings"][0]["rule"] == "JP102"
    assert payload["new_findings"][0]["fix_hint"]


def test_cli_parse_error_exits_two(tmp_path, capsys):
    _write(tmp_path, "def broken(:\n")
    assert main([str(tmp_path)]) == 2
    assert "parse error" in capsys.readouterr().err


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out
