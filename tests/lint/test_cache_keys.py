"""CK family: true positives and false-positive guards."""


def test_unused_param_flagged(rule_ids):
    assert "CK401" in rule_ids("""
        def artifact_key(tid, seed, line_size):
            return f"{tid}-s{seed}"
    """)


def test_all_params_interpolated_clean(rule_ids):
    assert rule_ids("""
        def artifact_key(tid, seed, line_size):
            return f"{tid}-s{seed}-l{line_size}"
    """) == []


def test_transitive_flow_through_locals_clean(rule_ids):
    # params flowing via intermediate assignments and .append() count
    assert rule_ids("""
        def bucket_key(n, m, window):
            parts = [str(n)]
            parts.append(str(m))
            w = window or 0
            parts.append(f"w{w}")
            return "-".join(parts)
    """) == []


def test_unused_self_attr_flagged(rule_ids):
    assert "CK401" in rule_ids("""
        class Builder:
            @property
            def store_fingerprint(self):
                tag = "mimic" if self.binned else "mimic"
                _ = self.seed
                return tag
    """)


def test_control_dependent_attr_clean(rule_ids):
    # a field steering the return via a branch shapes the key too
    assert rule_ids("""
        class Buffer:
            def frontier_key(self, chunk):
                if self.done:
                    return float("inf")
                return (self.start + len(self.addr)) // chunk
    """) == []


def test_non_key_function_not_checked(rule_ids):
    assert rule_ids("""
        def transform(a, b):
            return a
    """) == []


def test_store_version_without_key_path_flagged(rule_ids):
    assert "CK402" in rule_ids("""
        STORE_VERSION = 2

        class Store:
            def _dir(self, kind):
                return self.root / kind
    """)


def test_store_version_in_key_path_clean(rule_ids):
    assert rule_ids("""
        STORE_VERSION = 2

        class Store:
            def __init__(self, root, version=STORE_VERSION):
                self.root = root
                self.version = version

            def _dir(self, kind):
                return self.root / f"v{self.version}" / kind
    """) == []


def test_meta_field_written_not_read_flagged(rule_ids):
    assert "CK403" in rule_ids("""
        def save_cell(store, art):
            store.put_json("cell", "k", meta={"cores": art.cores,
                                              "flavor": art.flavor})

        def load_cell(store):
            meta = store.get_json("cell", "k")
            return meta["cores"]
    """)


def test_meta_field_read_not_written_flagged(rule_ids):
    assert "CK403" in rule_ids("""
        def save_cell(store, art):
            store.put_json("cell", "k", meta={"cores": art.cores})

        def load_cell(store):
            meta = store.get_json("cell", "k")
            return meta["cores"], meta.get("flavor")
    """)


def test_symmetric_meta_clean(rule_ids):
    assert rule_ids("""
        def save_cell(store, art):
            store.put_json("cell", "k", meta={"cores": art.cores,
                                              "seed": art.seed})

        def load_cell(store):
            meta = store.get_json("cell", "k")
            return meta["cores"], meta.get("seed")
    """) == []


def test_arrays_dict_not_mistaken_for_meta(rule_ids):
    # put_arrays(kind, key, arrays, meta): only the trailing dict is
    # the persisted meta — payload array names are not meta fields
    assert rule_ids("""
        def save_cell(store, art):
            store.put_arrays(
                "cell", "k",
                {"distances": art.distances, "counts": art.counts},
                {"cores": art.cores},
            )

        def load_cell(store):
            arrays, meta = store.get_arrays("cell", "k")
            return arrays["counts"], meta["cores"]
    """) == []


def test_unpaired_save_not_checked(rule_ids):
    assert rule_ids("""
        def save_orphan(store):
            store.put_json("cell", "k", meta={"cores": 4})
    """) == []
