"""CC family: true positives and false-positive guards."""


def test_unlocked_write_flagged(rule_ids):
    # the MicroBatcher.start() bug shape: flag written under the lock in
    # stop() but bare in start()
    assert "CC301" in rule_ids("""
        import threading

        class Batcher:
            def __init__(self):
                self._lock = threading.Lock()
                self._stopped = False

            def start(self):
                self._stopped = False

            def stop(self):
                with self._lock:
                    self._stopped = True
    """)


def test_locked_access_clean(rule_ids):
    assert rule_ids("""
        import threading

        class Batcher:
            def __init__(self):
                self._lock = threading.Lock()
                self._stopped = False

            def start(self):
                with self._lock:
                    self._stopped = False

            def stop(self):
                with self._lock:
                    self._stopped = True
    """) == []


def test_init_writes_exempt(rule_ids):
    # publication in __init__ happens-before any other thread sees self
    assert rule_ids("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def bump(self):
                with self._lock:
                    self.count += 1
    """) == []


def test_locked_helper_method_exempt(rule_ids):
    # `*_locked` helpers are called with the lock already held
    assert rule_ids("""
        import threading

        class Store:
            def __init__(self):
                self._lock = threading.Lock()
                self._cache = {}

            def put(self, k, v):
                with self._lock:
                    self._cache = {**self._cache, k: v}
                    self._evict_locked()

            def _evict_locked(self):
                self._cache = {}
    """) == []


def test_write_through_counter_guarded(rule_ids):
    # `self.stats.shed += 1` under the lock guards `stats`
    assert "CC301" in rule_ids("""
        import threading

        class Service:
            def __init__(self):
                self._lock = threading.Lock()
                self.stats = object()

            def shed(self):
                with self._lock:
                    self.stats.shed += 1

            def snapshot(self):
                return self.stats.shed
    """)


def test_unguarded_attrs_clean(rule_ids):
    # attributes never written under a lock carry no lock contract
    assert rule_ids("""
        import threading

        class Runner:
            def __init__(self):
                self._lock = threading.Lock()
                self._running = False

            def start(self):
                self._running = True

            def locked_work(self):
                with self._lock:
                    pass
    """) == []


def test_lock_order_conflict_flagged(rule_ids):
    assert "CC302" in rule_ids("""
        import threading

        class TwoLocks:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._b:
                    with self._a:
                        pass
    """)


def test_consistent_lock_order_clean(rule_ids):
    assert rule_ids("""
        import threading

        class TwoLocks:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._a:
                    with self._b:
                        pass
    """) == []


def test_stranded_future_flagged(rule_ids):
    # resolved on one branch only, then dropped
    assert "CC303" in rule_ids("""
        from concurrent.futures import Future

        def submit(ok):
            fut = Future()
            if ok:
                fut.set_result(1)
            return None
    """)


def test_future_resolved_on_all_branches_clean(rule_ids):
    assert rule_ids("""
        from concurrent.futures import Future

        def submit(ok):
            fut = Future()
            if ok:
                fut.set_result(1)
            else:
                fut.set_exception(ValueError("no"))
            return fut.result()
    """) == []


def test_future_returned_clean(rule_ids):
    # handing the future to the caller discharges responsibility
    assert rule_ids("""
        from concurrent.futures import Future

        def submit(queue, item):
            fut = Future()
            queue.put((item, fut))
            return fut
    """) == []


def test_future_resolved_in_except_clean(rule_ids):
    assert rule_ids("""
        from concurrent.futures import Future

        def submit(work):
            fut = Future()
            try:
                fut.set_result(work())
            except Exception as exc:
                fut.set_exception(exc)
            return fut
    """) == []
