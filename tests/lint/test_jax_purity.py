"""JP family: true positives and false-positive guards."""


def test_print_in_jit_flagged(rule_ids):
    assert "JP101" in rule_ids("""
        import jax

        @jax.jit
        def f(x):
            print("tracing", x)
            return x + 1
    """)


def test_print_outside_jit_clean(rule_ids):
    assert rule_ids("""
        import jax

        def f(x):
            print(x)
            return x
    """) == []


def test_jax_debug_print_allowed(rule_ids):
    assert rule_ids("""
        import jax

        @jax.jit
        def f(x):
            jax.debug.print("x={}", x)
            return x
    """) == []


def test_float_cast_on_traced_flagged(rule_ids):
    assert "JP102" in rule_ids("""
        import jax

        @jax.jit
        def f(x):
            return float(x) * 2
    """)


def test_item_on_traced_flagged(rule_ids):
    assert "JP102" in rule_ids("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            total = jnp.sum(x)
            return total.item()
    """)


def test_int_on_static_arg_clean(rule_ids):
    # static_argnums values are concrete Python ints under tracing
    assert rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, n):
            return x * int(n)
    """) == []


def test_helper_with_static_callsite_arg_clean(rule_ids):
    # the _fenwick_levels pattern: a helper reachable from jit code is
    # only as tainted as its call sites — int() on a shape-derived
    # argument is not a host sync
    assert rule_ids("""
        import jax

        def _levels(n):
            return max(1, int(n).bit_length())

        @jax.jit
        def scan(tree):
            size = tree.shape[0]
            k = _levels(size)
            return tree * k
    """) == []


def test_helper_with_traced_callsite_arg_flagged(rule_ids):
    # same helper, but a caller feeds it traced data
    assert "JP102" in rule_ids("""
        import jax

        def _levels(n):
            return int(n)

        @jax.jit
        def scan(tree):
            return tree * _levels(tree[0])
    """)


def test_numpy_on_traced_flagged(rule_ids):
    assert "JP103" in rule_ids("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x).sum()
    """)


def test_numpy_on_host_value_clean(rule_ids):
    assert rule_ids("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            table = np.arange(16)
            return x + table
    """) == []


def test_if_on_traced_flagged(rule_ids):
    assert "JP110" in rule_ids("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)


def test_if_on_config_value_clean(rule_ids):
    # Python branches on static config are the normal jit idiom
    assert rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("causal",))
        def f(x, causal):
            if causal:
                return x * 2
            return x
    """) == []


def test_is_none_check_on_traced_clean(rule_ids):
    # optional-argument plumbing: `w if w is None` is resolved at trace
    # time regardless of w being traced afterwards
    assert rule_ids("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, w):
            if w is None:
                w = jnp.ones_like(x)
            return x * w
    """) == []


def test_while_on_traced_flagged(rule_ids):
    assert "JP110" in rule_ids("""
        import jax

        @jax.jit
        def f(x):
            while x > 0:
                x = x - 1
            return x
    """)


def test_for_over_shape_range_clean(rule_ids):
    assert rule_ids("""
        import jax

        @jax.jit
        def f(x):
            for _ in range(x.ndim):
                x = x.sum(axis=-1)
            return x
    """) == []


def test_vmapped_helper_params_are_traced(rule_ids):
    # helpers passed by reference (vmap/scan) receive tracers for every
    # parameter even without a direct call site
    assert "JP110" in rule_ids("""
        import jax

        def row(x):
            if x > 0:
                return x
            return -x

        @jax.jit
        def f(xs):
            return jax.vmap(row)(xs)
    """)


def test_jit_wrap_assignment_is_a_root(rule_ids):
    assert "JP102" in rule_ids("""
        import jax

        def f(x):
            return float(x)

        g = jax.jit(f)
    """)


def test_jit_in_loop_flagged(rule_ids):
    assert "JP120" in rule_ids("""
        import jax

        def run(fns, x):
            outs = []
            for fn in fns:
                outs.append(jax.jit(fn)(x))
            return outs
    """)


def test_jit_factory_outside_loop_clean(rule_ids):
    assert rule_ids("""
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def _scan_fn(cap):
            @functools.partial(jax.jit, donate_argnums=(0,))
            def run(tree, xs):
                return tree + xs

            return run
    """) == []


def test_static_arg_from_len_flagged(rule_ids):
    assert "JP121" in rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, n):
            return x[:n]

        def driver(x, xs):
            return f(x, len(xs))
    """)


def test_static_arg_from_bucketed_constant_clean(rule_ids):
    assert rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, n):
            return x[:n]

        def driver(x):
            return f(x, 128)
    """) == []


def test_no_jax_import_no_jp(rule_ids):
    # modules that never import jax are out of the JP family's scope
    assert rule_ids("""
        def f(x):
            print(x)
            if x > 0:
                return float(x)
            return 0.0
    """) == []
