"""DN family: true positives and false-positive guards."""


def test_undonated_carry_flagged(rule_ids):
    assert "DN201" in rule_ids("""
        import jax

        @jax.jit
        def step(tree, xs):
            return tree + xs, xs

        def drive(tree, batches):
            for xs in batches:
                tree, _ = step(tree, xs)
            return tree
    """)


def test_donated_carry_clean(rule_ids):
    assert rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(tree, xs):
            return tree + xs, xs

        def drive(tree, batches):
            for xs in batches:
                tree, _ = step(tree, xs)
            return tree
    """) == []


def test_attribute_carry_flagged(rule_ids):
    # the fused.py pattern: self._hist is the carry
    assert "DN201" in rule_ids("""
        import jax

        @jax.jit
        def accumulate(hist, xs):
            return hist + xs

        class Sink:
            def push(self, xs):
                self._hist = accumulate(self._hist, xs)
    """)


def test_factory_returned_callable_donation_tracked(rule_ids):
    # `run = factory(cap)` inherits the nested def's donate_argnums
    assert rule_ids("""
        import functools
        import jax

        def _scan_fn(cap):
            @functools.partial(jax.jit, donate_argnums=(0,))
            def run(tree, xs):
                return tree + xs, xs

            return run

        def drive(tree, xs):
            run = _scan_fn(8)
            tree, _ = run(tree, xs)
            return tree
    """) == []


def test_factory_call_args_not_buffers(rule_ids):
    # cap/block handed to the *factory* are static config, not donated
    # buffers — reading them afterwards is fine
    assert rule_ids("""
        import functools
        import jax

        def _scan_fn(cap, block):
            @functools.partial(jax.jit, donate_argnums=(0, 1))
            def run(tree, slot, xs):
                return tree, slot, xs

            return run

        def drive(tree, slot, xs, cap):
            run = _scan_fn(cap, 64)
            tree, slot, out = run(tree, slot, xs)
            return out[:cap]
    """) == []


def test_non_carry_args_clean(rule_ids):
    # result does not rebind any argument: nothing to donate
    assert rule_ids("""
        import jax

        @jax.jit
        def f(a, b):
            return a + b

        def drive(a, b):
            out = f(a, b)
            return out
    """) == []


def test_use_after_donation_flagged(rule_ids):
    assert "DN202" in rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(tree, xs):
            return tree + xs

        def drive(tree, xs):
            out = step(tree, xs)
            return tree.sum() + out
    """)


def test_rebind_then_read_clean(rule_ids):
    assert rule_ids("""
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(tree, xs):
            return tree + xs

        def drive(tree, xs):
            tree = step(tree, xs)
            return tree.sum()
    """) == []
