"""Shared test config.

``hypothesis`` is an optional test dependency (declared as the
``test`` extra in pyproject.toml).  When it isn't installed, a minimal
stub is registered so modules using ``@given`` still import — each
property-based test then skips cleanly instead of erroring the whole
file's collection, and the plain tests in those files keep running.
"""
from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is present
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (pip install "
                            "'.[test]' to run property-based tests)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Inert stand-in: supports chaining/combinator calls."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    stub.assume = lambda *a, **k: True
    stub.example = _given
    stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    strategies = _Strategies("hypothesis.strategies")
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
