"""Shared test config.

``hypothesis`` is an optional test dependency (declared in the ``test``
extra, pulled in by ``dev``; CI installs it).  When it isn't installed,
``tests/_hypothesis_fallback.py`` registers a minimal but *functional*
random-testing engine under the same import names — property suites
actually execute their predicates (deterministic per-test seeds, corner
cases first) instead of silently skipping like the old inert stub did.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
