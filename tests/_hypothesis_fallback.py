"""Functional stand-in for ``hypothesis`` when it is not installed.

The old conftest shim registered an *inert* stub: every ``@given`` test
silently skipped, so the property suites (reuse-distance oracles, SDCM
monotonicity, sampling unbiasedness, ...) never ran in a bare
environment.  This module is a minimal but REAL random-testing engine
covering exactly the subset of the hypothesis API the test suites use:

* strategies: ``integers(min_value, max_value)``,
  ``floats(min_value, max_value)``, ``lists(elements, min_size,
  max_size)``, ``sampled_from(seq)``, ``tuples(*strategies)``
* ``@given`` with positional or keyword strategies (positional
  strategies bind to the function's rightmost parameters, like
  hypothesis, so fixtures can occupy the left)
* ``@settings(max_examples=..., deadline=...)`` above or below
  ``@given``
* ``assume(cond)`` — discards the current example

Determinism: every test draws from a PRNG seeded by its own qualified
name, so a failure reproduces run over run.  The first two examples are
the all-minimal and all-maximal corners (empty lists, bound endpoints)
— the cheap shrunk cases hypothesis would try first — and the rest are
uniform draws.  There is no shrinking; the falsifying example is
attached to the exception instead.

When the real ``hypothesis`` is installed (the ``test`` extra, CI),
conftest never imports this module.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 50


class Unsatisfied(Exception):
    """Raised by ``assume(False)`` — discards the current example."""


def assume(condition) -> bool:
    if not condition:
        raise Unsatisfied()
    return True


# --- strategies -------------------------------------------------------------
#
# ``phase`` 0 draws every strategy's minimal corner, 1 the maximal one,
# anything else a uniform random value.


class Strategy:
    def draw(self, rng: random.Random, phase: int):
        raise NotImplementedError


class _Integers(Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(1 << 16) if min_value is None else int(min_value)
        self.hi = (1 << 16) if max_value is None else int(max_value)
        if self.lo > self.hi:
            raise ValueError(f"integers: min {self.lo} > max {self.hi}")

    def draw(self, rng, phase):
        if phase == 0:
            return self.lo
        if phase == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(self, min_value=None, max_value=None, *,
                 allow_nan=False, allow_infinity=False):
        # bounded draws only: NaN/inf never produced, the flags exist
        # for signature compatibility
        self.lo = -1e6 if min_value is None else float(min_value)
        self.hi = 1e6 if max_value is None else float(max_value)
        if self.lo > self.hi:
            raise ValueError(f"floats: min {self.lo} > max {self.hi}")

    def draw(self, rng, phase):
        if phase == 0:
            return self.lo
        if phase == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = (self.min_size + 16 if max_size is None
                         else int(max_size))

    def draw(self, rng, phase):
        if phase == 0:
            n = self.min_size
        elif phase == 1:
            n = self.max_size
        else:
            n = rng.randint(self.min_size, self.max_size)
        return [self.elements.draw(rng, phase) for _ in range(n)]


class _SampledFrom(Strategy):
    def __init__(self, seq):
        self.seq = list(seq)
        if not self.seq:
            raise ValueError("sampled_from: empty sequence")

    def draw(self, rng, phase):
        if phase == 0:
            return self.seq[0]
        if phase == 1:
            return self.seq[-1]
        return rng.choice(self.seq)


class _Tuples(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def draw(self, rng, phase):
        return tuple(s.draw(rng, phase) for s in self.strategies)


def integers(min_value=None, max_value=None) -> Strategy:
    return _Integers(min_value, max_value)


def floats(min_value=None, max_value=None, **kw) -> Strategy:
    return _Floats(min_value, max_value, **kw)


def lists(elements, min_size=0, max_size=None) -> Strategy:
    return _Lists(elements, min_size, max_size)


def sampled_from(seq) -> Strategy:
    return _SampledFrom(seq)


def tuples(*strategies) -> Strategy:
    return _Tuples(*strategies)


# --- decorators -------------------------------------------------------------


def settings(*args, max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
    """Record ``max_examples``; ``deadline``/profiles are ignored.

    Works above OR below ``@given``: the attribute is read lazily at
    call time, and both decorators return the same function object they
    received (mutated), so ordering cannot drop it.
    """

    def deco(fn):
        fn._fallback_settings = {"max_examples": int(max_examples)}
        return fn

    if args and callable(args[0]):  # bare ``@settings`` usage
        return deco(args[0])
    return deco


def given(*arg_strategies, **kw_strategies):
    bad = [s for s in (*arg_strategies, *kw_strategies.values())
           if not isinstance(s, Strategy)]
    if bad:
        raise TypeError(f"@given expects strategies, got {bad!r}")

    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # positional strategies bind rightmost (hypothesis convention,
        # keeps self/fixtures on the left)
        strat_map = dict(zip(names[len(names) - len(arg_strategies):],
                             arg_strategies))
        overlap = strat_map.keys() & kw_strategies.keys()
        if overlap:
            raise TypeError(f"@given got {sorted(overlap)} both "
                            "positionally and by keyword")
        strat_map.update(kw_strategies)
        unknown = [n for n in strat_map if n not in names]
        if unknown:
            raise TypeError(f"@given strategies {unknown} do not match "
                            f"parameters of {fn.__qualname__}")
        remaining = [p for p in sig.parameters.values()
                     if p.name not in strat_map]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_fallback_settings", None) or \
                getattr(fn, "_fallback_settings", None) or {}
            max_examples = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            qualname = f"{fn.__module__}.{fn.__qualname__}"
            seed = int.from_bytes(
                hashlib.sha1(qualname.encode()).digest()[:8], "big"
            )
            rng = random.Random(seed)
            ran, attempts = 0, 0
            # assume() discards don't count as examples, but a filter
            # that rejects nearly everything must terminate loudly
            while ran < max_examples:
                if attempts > max_examples * 10 + 100:
                    raise RuntimeError(
                        f"{qualname}: assume() rejected too many "
                        f"examples ({attempts} attempts for {ran} runs)"
                    )
                attempts += 1
                phase = ran if ran < 2 else 2
                drawn = {n: s.draw(rng, phase)
                         for n, s in strat_map.items()}
                try:
                    fn(*args, **{**kwargs, **drawn})
                except Unsatisfied:
                    continue
                except Exception as exc:
                    note = (f"falsifying example ({qualname}, "
                            f"seed={seed}): {drawn!r}")
                    if hasattr(exc, "add_note"):
                        exc.add_note(note)
                    else:  # pragma: no cover - pre-3.11
                        print(note, file=sys.stderr)
                    raise
                ran += 1

        # hide the strategy-bound parameters from pytest's fixture
        # resolution: only the remaining ones (normally none) are real
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


def example(*_args, **_kwargs):
    """No-op compatibility decorator (explicit examples are already
    covered by the deterministic corner phases)."""
    return lambda fn: fn


# --- module installation ----------------------------------------------------


def install() -> None:
    """Register ``hypothesis`` / ``hypothesis.strategies`` modules built
    from this engine (no-op if the real package is importable)."""
    if "hypothesis" in sys.modules:
        return
    strategies = types.ModuleType("hypothesis.strategies")
    for fn in (integers, floats, lists, sampled_from, tuples):
        setattr(strategies, fn.__name__, fn)
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.example = example
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None,
        function_scoped_fixture=None,
    )
    mod.strategies = strategies
    mod.__fallback__ = True  # lets tests detect the stand-in engine
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
