"""Serving walkthrough: concurrent clients against one
PredictionService, with coalescing and the shared disk store visible
in the counters.

    PYTHONPATH=src python examples/serve_predictions.py

Eight "clients" concurrently ask overlapping what-if questions about
two workloads; the microbatcher dedups and coalesces them, each batch
is one batched-SDCM kernel call, and the stats show how many
computations actually ran.  Run it twice: the second process serves
every reuse profile from ``.cache/service-demo`` with zero rebuilds.
"""
from __future__ import annotations

import threading

from repro.api import PredictionRequest
from repro.service import PredictionService, ServiceConfig
from repro.workloads.polybench import make_workload

ARTIFACT_DIR = ".cache/service-demo"


def main() -> None:
    atax = make_workload("atx", "smoke")
    mvt = make_workload("mvt", "smoke")
    questions = [
        (atax, PredictionRequest(
            targets=("i7-5960X", "EPYC 7702P"), core_counts=(1, 4, 8),
            counts=atax.op_counts, respect_core_limit=False)),
        (mvt, PredictionRequest(
            targets=("i7-5960X",), core_counts=(1, 2),
            counts=mvt.op_counts, respect_core_limit=False)),
    ]

    config = ServiceConfig(max_batch=32, max_wait_ms=20)
    with PredictionService(config=config,
                           artifact_dir=ARTIFACT_DIR) as svc:
        responses = []
        lock = threading.Lock()

        def client(n: int) -> None:
            workload, request = questions[n % len(questions)]
            resp = svc.predict(workload, request, timeout=300)
            with lock:
                responses.append((n, resp))

        threads = [threading.Thread(target=client, args=(n,))
                   for n in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        n, resp = min(responses)
        print(resp.result.to_table())
        print(f"\n8 concurrent requests -> "
              f"{svc.stats.coalesced} unique computations in "
              f"{svc.stats.batches} batches "
              f"(mean size {svc.stats.mean_batch_size:.1f}, "
              f"{svc.stats.deduped} deduped)")
        print(f"profile builds this process: "
              f"{svc.session.stats.profile_builds} "
              f"(disk hits: {svc.session.stats.store_hits} — rerun me "
              f"and this process rebuilds nothing)")


if __name__ == "__main__":
    main()
