"""Paper-validation walkthrough: a slice of the Tables 6-8 matrix.

Runs a handful of workloads through ``repro.validate`` — the same
harness the committed ``docs/validation.md`` report comes from — with
a disk artifact store, then prints the per-architecture errors next to
the paper's claimed figures and proves the incrementality property by
running the slice a second time.

    PYTHONPATH=src python examples/validate_paper.py

The full matrix (all 14 workloads x 3 CPUs x cores {1,2,4,8} x two
interleave strategies) is the CLI:

    PYTHONPATH=src python -m repro.validate --artifact-dir .validation-cache
"""
from repro.validate import MatrixSpec, paper_claim, run_validation

SPEC = MatrixSpec(
    workloads=("atx", "mvt", "grm", "blk"),
    core_counts=(1, 4),
    strategies=("round_robin",),
    sizes="validation",
)
ARTIFACTS = ".cache/validate-example"

print(f"matrix slice: {SPEC.describe()}\n")
summary = run_validation(SPEC, artifact_dir=ARTIFACTS, processes=1)

print(f"{'architecture':<18} {'hit err %':>10} {'paper':>7} "
      f"{'runtime err %':>14} {'paper':>7}")
for arch, entry in sorted(summary["aggregates"]["per_arch"].items()):
    claim = paper_claim(arch)
    print(f"{arch:<18} {entry['hit_rate_err_pct']['ours']:>10.2f} "
          f"{claim.hit_rate_err_pct:>7.2f} "
          f"{entry['runtime_err_pct']['ours']:>14.2f} "
          f"{claim.runtime_err_pct:>7.2f}")
agg = summary["aggregates"]["overall"]
print(f"{'overall':<18} {agg['hit_rate_err_pct']['ours']:>10.2f} "
      f"{agg['hit_rate_err_pct']['paper']:>7.2f} "
      f"{agg['runtime_err_pct']['ours']:>14.2f} "
      f"{agg['runtime_err_pct']['paper']:>7.2f}")

stats = summary["session_stats"]
print(f"\nrun 1: {stats['profile_builds']} profile builds, "
      f"{stats['store_hits']} disk-store hits")

# Incrementality: the store makes the second run free of profile work.
again = run_validation(SPEC, artifact_dir=ARTIFACTS, processes=1)
s2 = again["session_stats"]
print(f"run 2: {s2['profile_builds']} profile builds, "
      f"{s2['store_hits']} disk-store hits  "
      f"(zero reuse-profile recomputations)")
assert s2["profile_builds"] == 0 and s2["rd_builds"] == 0
