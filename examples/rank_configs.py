"""Example 4 — the paper's technique as a first-class framework
feature: rank candidate configurations *before compiling them*.

PPT-Multicore's selling point is pricing core counts / cache designs
from one trace.  Translated to this framework: price (arch x shape)
cells from the dry-run artifacts — three roofline terms + the reuse-
profile VMEM refinement — and rank the bottlenecks, without any new
compile.

    PYTHONPATH=src python examples/rank_configs.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/
from benchmarks.roofline_table import load_records, roofline_from_record

records = [r for r in load_records("pod") if r["status"] == "ok"]
if not records:
    raise SystemExit(
        "no dry-run records; run: PYTHONPATH=src python -m "
        "repro.launch.dryrun --all --mesh pod")

rows = [roofline_from_record(r) for r in records]
rows.sort(key=lambda r: r.roofline_fraction)

print(f"{len(rows)} compiled cells, ranked worst-first by roofline "
      f"fraction:\n")
print(f"{'cell':<38} {'bound':<11} {'t_bound':>9} {'roofl%':>7}")
for r in rows:
    cell = f"{r.arch} x {r.shape}"
    print(f"{cell:<38} {r.bottleneck:<11} {r.t_step_bound_s:>8.4f}s "
          f"{100 * r.roofline_fraction:>6.1f}%")

worst = rows[0]
coll = max(rows, key=lambda r: r.collective_s / max(r.t_step_bound_s, 1e-12))
print(f"\nhillclimb picks -> worst fraction: {worst.arch} x {worst.shape}; "
      f"most collective-bound: {coll.arch} x {coll.shape}")
