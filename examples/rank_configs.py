"""Example 4 — the paper's technique as a first-class framework
feature: rank candidate configurations *before running them*.

PPT-Multicore's selling point is pricing core counts / cache designs
from one trace.  With `repro.api` that is one declarative request: the
Session executes the whole (target x cores x strategy) grid off a
single ATAX trace — each profile computed once — and the cells rank by
predicted runtime.  When dry-run artifacts exist, the TPU roofline
ranking (arch x shape cells) is printed as well.

    PYTHONPATH=src python examples/rank_configs.py
"""
import sys
from pathlib import Path

from repro.api import PredictionRequest, Session
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import make_atax

workload = make_atax(n=96)
session = Session()
request = PredictionRequest(
    targets=tuple(CPU_TARGETS),
    core_counts=(1, 2, 4, 8, 16),
    strategies=("round_robin", "uniform"),
    counts=workload.op_counts,
)
result = session.predict(workload, request)

cells = sorted(result, key=lambda p: p.t_pred_s)
print(f"{len(cells)} predicted cells for {workload.name}, ranked "
      f"best-first by T_pred (one trace, zero reruns):\n")
print(f"{'target':<17} {'cores':>5} {'strategy':<12} "
      f"{'LLC P(h)':>9} {'T_pred':>11}")
for p in cells:
    llc = list(p.hit_rates.values())[-1]
    print(f"{p.target:<17} {p.cores:>5} {p.strategy:<12} "
          f"{llc:>9.4f} {p.t_pred_s:>10.3e}s")

best, worst = cells[0], cells[-1]
print(f"\npick: {best.target} @ {best.cores} cores ({best.strategy}) — "
      f"{worst.t_pred_s / best.t_pred_s:.1f}x faster than the worst cell; "
      f"{session.stats.profile_builds} profile builds served "
      f"{len(cells)} cells")

# --- optional: TPU roofline ranking from dry-run records --------------------
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/
from benchmarks.roofline_table import load_records, roofline_from_record

records = [r for r in load_records("pod") if r["status"] == "ok"]
if not records:
    print("\n(no dry-run records; for the TPU roofline ranking run: "
          "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod)")
    raise SystemExit(0)

rows = [roofline_from_record(r) for r in records]
rows.sort(key=lambda r: r.roofline_fraction)
print(f"\n{len(rows)} compiled TPU cells, ranked worst-first by roofline "
      f"fraction:\n")
print(f"{'cell':<38} {'bound':<11} {'t_bound':>9} {'roofl%':>7}")
for r in rows:
    cell = f"{r.arch} x {r.shape}"
    print(f"{cell:<38} {r.bottleneck:<11} {r.t_step_bound_s:>8.4f}s "
          f"{100 * r.roofline_fraction:>6.1f}%")
