"""Example 3 — batched serving of a (reduced) MoE model with sliding-
window attention: prefill once, decode with KV-cache reuse.

    PYTHONPATH=src python examples/serve_moe.py
"""
from repro.launch.serve import main as serve_main

rc = serve_main([
    "--arch", "mixtral-8x7b", "--reduced",
    "--batch", "4", "--prompt-len", "32", "--gen", "12",
    "--temperature", "0.8",
])
assert rc == 0
