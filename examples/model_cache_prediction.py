"""Example — what VMEM hit rate does llama3-8b decode see on the TPU?

The workload registry makes a model step a first-class trace source:
``model/llama3_8b/decode`` lowers the decode step to optimized HLO
(plain jit, abstract operands — nothing allocated), extracts the
granule-labeled memory trace, and the same SDCM pipeline that prices
the PolyBench suite prices the 128 MB VMEM.  The declared fingerprint
keys the artifact store, so the second invocation of this script
performs zero lowerings and zero trace builds.

    PYTHONPATH=src python examples/model_cache_prediction.py
    PYTHONPATH=src python examples/model_cache_prediction.py  # warm
"""
from repro.api import PredictionRequest, Session
from repro.workloads import registry

session = Session(artifact_dir=".cache/model-artifacts")
workload = registry.resolve("model/llama3_8b/decode", "smoke",
                            store=session.store)
print(f"{workload.workload_name}  "
      f"(declared fingerprint {workload.declared_fingerprint})")

request = PredictionRequest(
    targets=("tpu-v5e",),
    core_counts=(1,),                 # VMEM is shared by all compute units
    counts=workload.op_counts,        # HLO cost model -> roofline runtime
)
result = session.predict(workload, request)

for cell in result.predictions:
    print(f"  VMEM hit rate @ batch 32: {cell.hit_rates['VMEM']:.4f}   "
          f"t_pred = {cell.t_pred_s * 1e6:.2f} us/step")
print(f"  trace builds this run: {session.stats.trace_builds} "
      f"(store hits: {session.stats.store_hits})")
