"""Streaming pipeline walkthrough: same grid, O(window) scan memory.

The paper's traces are collected once and can be enormous; the
streaming layer (ISSUE-2) bounds the reuse-distance scan state by the
window + working set instead of the trace length, while staying
BIT-identical to the in-memory oracle.

    PYTHONPATH=src python examples/streaming_predict.py
"""
import numpy as np

from repro.api import PredictionRequest, Session
from repro.core.reuse.distance import (
    reuse_distance_windows,
    reuse_distances,
)
from repro.core.reuse.profile import (
    profile_from_distances,
    profile_from_distances_incremental,
)
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import make_atax

WINDOW = 1 << 12

# 1. The same declarative grid as examples/quickstart.py, but every
#    reuse-distance pass runs through the chunked Fenwick scan and the
#    shared trace is consumed as merged windows, never concatenated.
workload = make_atax(n=96)
trace = workload.trace()
request = PredictionRequest(
    targets=tuple(CPU_TARGETS),
    core_counts=(1, 2, 4, 8),
    counts=workload.op_counts,
)

in_memory = Session().predict(trace, request)
streaming = Session(window_size=WINDOW).predict(trace, request)
print(streaming.to_table())

for cell in in_memory:
    other = streaming.one(target=cell.target, cores=cell.cores)
    assert other.hit_rates == cell.hit_rates  # exact, not approximate
print(f"\nstreaming (window={WINDOW}) == in-memory on all "
      f"{len(in_memory)} grid cells, bit-for-bit")

# 2. The pieces compose directly: an incremental profile from distance
#    windows — the O(N) distance array is never materialized.
addrs = trace.addresses
prof_stream = profile_from_distances_incremental(
    reuse_distance_windows(addrs, 64, window_size=WINDOW)
)
prof_ref = profile_from_distances(reuse_distances(addrs, 64))
assert np.array_equal(prof_stream.distances, prof_ref.distances)
assert np.array_equal(prof_stream.counts, prof_ref.counts)
print(f"incremental profile: {len(prof_stream.distances)} distinct "
      f"distances over {prof_stream.total:,} refs — identical to the "
      f"monolithic pass")
