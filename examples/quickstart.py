"""Quickstart: the paper's pipeline end-to-end through `repro.api`.

One sequential trace of a parallel kernel (ATAX) in; cache hit rates
and runtimes for EVERY (target x core count) cell out — without
re-tracing.  This is PPT-Multicore's headline property (§1:
"predictions for various core counts without having to rerun the
application"), and the Session makes it an API invariant: each reuse
profile is computed exactly once across the whole grid — and, with an
``artifact_dir``, across *processes and runs*: the disk-backed
ArtifactStore persists every profile under content-hash keys, so
rerunning this script rebuilds nothing (watch ``store_hits`` flip
from 0 to 4 on the second invocation).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py   # all cells from disk
"""
from repro.api import PredictionRequest, Session
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import make_atax

# 1. The ROSE/Byfl stand-in: the parallel section's labeled memory
#    trace from ONE sequential execution (shared arrays labeled).
workload = make_atax(n=96)
trace = workload.trace()
print(f"traced {workload.name}: {len(trace):,} refs, "
      f"{trace.shared_mask.mean():.0%} shared")

# 2. One declarative request: every target x core count from that
#    single trace, executed by a caching Session.  The cache is NOT
#    per-process: artifact_dir layers a disk-backed store (atomic,
#    content-hash-keyed npz) under the in-memory dicts, so profiles
#    built here are reused by every later process that points at the
#    same directory — docs/architecture.md, repro/validate/store.py.
session = Session(artifact_dir=".cache/quickstart-artifacts")
request = PredictionRequest(
    targets=tuple(CPU_TARGETS),          # registry names work too
    core_counts=(1, 2, 4, 8),
    counts=workload.op_counts,
)
result = session.predict(trace, request)
print()
print(result.to_table())
print(f"\nartifact cache: {session.stats.profile_builds} profile builds, "
      f"{session.stats.profile_hits} in-memory hits, "
      f"{session.stats.store_hits} disk-store hits across "
      f"{len(result)} grid cells")

# 3. Validate one point against the exact LRU simulator (PAPI stand-in)
#    — the ground-truth model runs through the same stage interface.
target = next(iter(CPU_TARGETS.values()))
pred = result.one(target=target.name, cores=4).hit_rates
exact = session.ground_truth_hit_rates(trace, target, 4)
print(f"\nSDCM vs exact LRU on {target.name} @4 cores:")
for lvl in pred:
    print(f"  {lvl}: predicted {pred[lvl]:.4f}  exact {exact[lvl]:.4f}  "
          f"|err| {abs(pred[lvl] - exact[lvl]) * 100:.2f}%")
