"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

One sequential trace of a parallel kernel (ATAX) in; cache hit rates
and runtimes for EVERY core count out — without re-tracing.  This is
PPT-Multicore's headline property (§1: "predictions for various core
counts without having to rerun the application").

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.predictor import PPTMulticorePredictor
from repro.hw.targets import CPU_TARGETS
from repro.workloads.polybench import make_atax

# 1. The ROSE/Byfl stand-in: the parallel section's labeled memory
#    trace from ONE sequential execution (shared arrays labeled).
workload = make_atax(n=96)
trace = workload.trace()
print(f"traced {workload.name}: {len(trace):,} refs, "
      f"{trace.shared_mask.mean():.0%} shared")

# 2. Predict hit rates + runtime for every target and core count from
#    that single trace.
for target in CPU_TARGETS.values():
    print(f"\n=== {target.name} ({target.microarch}) ===")
    predictor = PPTMulticorePredictor(target)
    for cores in (1, 2, 4, 8):
        if cores > target.cores:
            continue
        pred = predictor.predict(trace, cores, workload.op_counts)
        rates = "  ".join(
            f"{k}={v:.3f}" for k, v in pred.hit_rates.items())
        print(f"  {cores} cores: {rates}  T_pred={pred.t_pred_s * 1e3:.2f} ms")

# 3. Validate one point against the exact LRU simulator (PAPI stand-in).
target = next(iter(CPU_TARGETS.values()))
predictor = PPTMulticorePredictor(target)
pred, _, _ = predictor.hit_rates(trace, 4)
exact = predictor.ground_truth_hit_rates(trace, 4)
print(f"\nSDCM vs exact LRU on {target.name} @4 cores:")
for lvl in pred:
    print(f"  {lvl}: predicted {pred[lvl]:.4f}  exact {exact[lvl]:.4f}  "
          f"|err| {abs(pred[lvl] - exact[lvl]) * 100:.2f}%")
