"""Example 2 — end-to-end training driver (deliverable b).

Trains a reduced llama3-family model for a few hundred steps on host
devices with checkpointing, then restarts from the checkpoint to prove
crash-safe resume.  The same driver scales to the production mesh
(--production-mesh on a real pod).

    PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch.train import main as train_main

ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
try:
    print("=== phase 1: train 120 steps with checkpoints ===")
    rc = train_main([
        "--arch", "llama3-8b", "--reduced", "--steps", "120",
        "--batch", "8", "--seq", "128",
        "--checkpoint-dir", ckpt, "--checkpoint-every", "50",
    ])
    assert rc == 0

    print("\n=== phase 2: simulate restart, resume to step 160 ===")
    rc = train_main([
        "--arch", "llama3-8b", "--reduced", "--steps", "160",
        "--batch", "8", "--seq", "128",
        "--checkpoint-dir", ckpt, "--resume",
    ])
    assert rc == 0
    print("\nresume OK — training is crash-safe.")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
